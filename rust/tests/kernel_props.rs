//! Property tests for the tiled compute kernels (PR: blocked level-3
//! rewrite): the packed GEMM and the blocked Householder QR are pitted
//! against the retained scalar references (`gemm_ref_into`,
//! `householder_qr_ref`) across odd shapes — tile-edge cases, `m < nb`
//! panels, zero columns — and the borrowed `MatrixView` ops are checked
//! to bit-match the old copying `block`/`set_block` path.
//!
//! The SIMD/parallel pins (PR: explicit-SIMD micro-kernels): every
//! runtime-available [`SimdLevel`] and every `ParCtx` band width must
//! reproduce the scalar serial product **bit-for-bit** — at adversarial
//! tile-edge shapes, under every `Trans` combination, and on strided
//! `MatrixView` sub-blocks. This is the determinism contract replay and
//! lookahead rest on; `assert_eq!` on `Matrix` compares exact bits.

use ftcaqr::linalg::{
    gemm, gemm_into, gemm_ref_into, gemm_view, gemm_view_into, gemm_view_into_with,
    gemm_with, householder_qr, householder_qr_blocked, householder_qr_par,
    householder_qr_ref, leaf_apply, leaf_apply_into, recover_block, recover_block_into,
    rel_err, tree_update, tree_update_half, tree_update_into, trmm_upper, tsqr_merge,
    Matrix, ParCtx, Rng64, SimdLevel, Trans,
};

fn ref_gemm(ta: Trans, tb: Trans, alpha: f32, a: &Matrix, b: &Matrix) -> Matrix {
    let (m, _) = if ta == Trans::No { a.shape() } else { (a.cols(), a.rows()) };
    let n = if tb == Trans::No { b.cols() } else { b.rows() };
    let mut c = Matrix::zeros(m, n);
    gemm_ref_into(ta, tb, alpha, a, b, 0.0, &mut c);
    c
}

#[test]
fn prop_gemm_matches_reference_across_odd_shapes() {
    // Shapes chosen to straddle every tile constant (MR=4, NR=16, MC=64,
    // KC=256, NC=256): singletons, non-multiples, and cross-boundary.
    let shapes = [
        (1usize, 1usize, 1usize),
        (2, 3, 1),
        (3, 5, 7),
        (4, 4, 16),
        (5, 17, 15),
        (16, 16, 17),
        (17, 19, 23),
        (31, 64, 65),
        (63, 33, 20),
        (65, 260, 13),
        (70, 40, 270),
    ];
    let mut seed = 100u64;
    for &(m, k, n) in &shapes {
        for (ta, tb) in [
            (Trans::No, Trans::No),
            (Trans::Yes, Trans::No),
            (Trans::No, Trans::Yes),
            (Trans::Yes, Trans::Yes),
        ] {
            seed += 1;
            let (ar, ac) = if ta == Trans::No { (m, k) } else { (k, m) };
            let (br, bc) = if tb == Trans::No { (k, n) } else { (n, k) };
            let a = Matrix::randn(ar, ac, seed);
            let b = Matrix::randn(br, bc, seed + 1000);
            let got = gemm(ta, tb, 1.0, &a, &b);
            let want = ref_gemm(ta, tb, 1.0, &a, &b);
            let err = rel_err(&got, &want);
            assert!(err < 1e-4, "({m},{k},{n}) {ta:?}/{tb:?}: rel err {err}");
        }
    }
}

#[test]
fn prop_gemm_alpha_beta_matches_reference() {
    let mut rng = Rng64::new(7);
    for _ in 0..8 {
        let m = 1 + rng.below(70);
        let k = 1 + rng.below(70);
        let n = 1 + rng.below(70);
        let a = Matrix::randn(m, k, rng.next_u64());
        let b = Matrix::randn(k, n, rng.next_u64());
        let c0 = Matrix::randn(m, n, rng.next_u64());
        let mut got = c0.clone();
        gemm_into(Trans::No, Trans::No, 1.5, &a, &b, -0.5, &mut got);
        let mut want = c0.clone();
        gemm_ref_into(Trans::No, Trans::No, 1.5, &a, &b, -0.5, &mut want);
        assert!(rel_err(&got, &want) < 1e-4, "({m},{k},{n})");
    }
}

#[test]
fn prop_gemm_zero_dims() {
    // Degenerate operands must not panic and must respect beta.
    let mut c = Matrix::randn(3, 4, 1);
    let before = c.clone();
    gemm_into(Trans::No, Trans::No, 1.0, &Matrix::zeros(3, 0), &Matrix::zeros(0, 4), 1.0, &mut c);
    assert_eq!(c, before, "k = 0 with beta = 1 is the identity");
    gemm_into(Trans::No, Trans::No, 1.0, &Matrix::zeros(3, 0), &Matrix::zeros(0, 4), 0.0, &mut c);
    assert_eq!(c, Matrix::zeros(3, 4), "k = 0 with beta = 0 zero-fills");
    assert_eq!(
        gemm(Trans::No, Trans::No, 1.0, &Matrix::zeros(0, 3), &Matrix::zeros(3, 5)).shape(),
        (0, 5)
    );
}

#[test]
fn prop_trmm_matches_gemm_for_triangular_t() {
    let mut rng = Rng64::new(21);
    for _ in 0..6 {
        let b = 1 + rng.below(40);
        let n = 1 + rng.below(80);
        let t = Matrix::randn(b, b, rng.next_u64()).triu();
        let x = Matrix::randn(b, n, rng.next_u64());
        for tt in [Trans::No, Trans::Yes] {
            let got = trmm_upper(tt, 1.0, &t, &x);
            let want = ref_gemm(tt, Trans::No, 1.0, &t, &x);
            assert!(rel_err(&got, &want) < 1e-4, "b={b} n={n} {tt:?}");
        }
    }
}

#[test]
fn prop_blocked_qr_matches_scalar_reference() {
    // (m, b) sweeps across the NB=16 sub-panel boundary, b < nb panels,
    // square panels, and tall-skinny leaves.
    let shapes = [
        (1usize, 1usize),
        (5, 3),
        (8, 8),
        (16, 16),
        (17, 5),
        (24, 16),
        (33, 7),
        (40, 32),
        (64, 48),
        (96, 64),
    ];
    for &(m, b) in &shapes {
        let a = Matrix::randn(m, b, (m * 100 + b) as u64);
        let blk = householder_qr(&a);
        let refr = householder_qr_ref(&a);
        assert!(rel_err(&blk.r, &refr.r) < 2e-4, "({m},{b}) r: {}", rel_err(&blk.r, &refr.r));
        assert!(rel_err(&blk.t, &refr.t) < 2e-4, "({m},{b}) t: {}", rel_err(&blk.t, &refr.t));
        assert!(rel_err(&blk.y, &refr.y) < 2e-4, "({m},{b}) y: {}", rel_err(&blk.y, &refr.y));
    }
}

#[test]
fn prop_blocked_qr_nb_sweep_consistent() {
    // Any sub-panel width must produce the same factorization (up to
    // rounding): nb = 1 degenerates to the scalar column loop, nb >= b
    // to a single unblocked panel.
    let a = Matrix::randn(48, 24, 77);
    let want = householder_qr_ref(&a);
    for nb in [1usize, 2, 3, 8, 16, 24, 64] {
        let got = householder_qr_blocked(&a, nb);
        assert!(rel_err(&got.r, &want.r) < 2e-4, "nb={nb} r");
        assert!(rel_err(&got.t, &want.t) < 2e-4, "nb={nb} t");
        assert!(rel_err(&got.y, &want.y) < 2e-4, "nb={nb} y");
    }
}

#[test]
fn prop_blocked_qr_rank_deficient_column() {
    // A duplicated column drives one reflector degenerate (zero segment)
    // mid-panel; both implementations must agree and stay finite.
    let mut a = Matrix::randn(12, 4, 9);
    for i in 0..12 {
        let v = a[(i, 0)];
        a[(i, 1)] = v;
    }
    let blk = householder_qr(&a);
    let refr = householder_qr_ref(&a);
    assert!(blk.y.data().iter().all(|x| x.is_finite()));
    assert!(blk.t.data().iter().all(|x| x.is_finite()));
    assert!(rel_err(&blk.r, &refr.r) < 2e-4);
    assert!(rel_err(&blk.y, &refr.y) < 2e-4);
    // Q R must still reproduce A.
    let q = {
        let yt = gemm(Trans::No, Trans::No, 1.0, &blk.y, &blk.t);
        let mut q = Matrix::eye(12);
        gemm_into(Trans::No, Trans::Yes, -1.0, &yt, &blk.y, 1.0, &mut q);
        q
    };
    let mut rfull = Matrix::zeros(12, 4);
    rfull.set_block(0, 0, &blk.r);
    let qr = gemm(Trans::No, Trans::No, 1.0, &q, &rfull);
    assert!(rel_err(&qr, &a) < 1e-3, "{}", rel_err(&qr, &a));
}

#[test]
fn prop_blocked_qr_zero_columns_exact() {
    let blk = householder_qr(&Matrix::zeros(20, 6));
    assert_eq!(blk.r.fro_norm(), 0.0);
    assert_eq!(blk.t.fro_norm(), 0.0);
    assert_eq!(blk.y.fro_norm(), 0.0);
}

#[test]
fn prop_view_gemm_bitmatches_copying_path() {
    // The strided-view path must produce bit-identical results to the
    // old copy-out/copy-in dance — this is what lets the coordinator
    // switch to views without perturbing replay bit-equality.
    let big_a = Matrix::randn(20, 18, 31);
    let big_b = Matrix::randn(17, 16, 32);
    let mut big_c = Matrix::randn(22, 19, 33);
    let (r0, c0, m, k) = (3, 2, 9, 7);
    let (r1, c1, n) = (4, 1, 11);
    let a_blk = big_a.block(r0, c0, m, k);
    let b_blk = big_b.block(r1, c1, k, n);
    let mut c_blk = big_c.block(5, 3, m, n);

    // copying path
    gemm_into(Trans::No, Trans::No, -1.0, &a_blk, &b_blk, 1.0, &mut c_blk);
    // view path
    gemm_view_into(
        Trans::No,
        Trans::No,
        -1.0,
        big_a.view(r0, c0, m, k),
        big_b.view(r1, c1, k, n),
        1.0,
        big_c.view_mut(5, 3, m, n),
    );
    assert_eq!(big_c.block(5, 3, m, n), c_blk, "view gemm must bit-match");

    // gemm_view == gemm on materialized blocks
    let v = gemm_view(Trans::Yes, Trans::No, 2.0, big_a.view(r0, c0, m, k), big_a.view(r0, c0, m, k));
    let w = gemm(Trans::Yes, Trans::No, 2.0, &a_blk, &a_blk);
    assert_eq!(v, w);
}

#[test]
fn prop_view_block_ops_bitmatch() {
    let a = Matrix::randn(15, 13, 41);
    assert_eq!(a.view(2, 3, 9, 8).to_matrix(), a.block(2, 3, 9, 8));
    assert_eq!(a.block_padded(2, 3, 9, 8, 12, 10), a.block(2, 3, 9, 8).pad_to(12, 10));
    let mut x = Matrix::zeros(15, 13);
    let mut y = Matrix::zeros(15, 13);
    x.set_block(4, 4, &a.block(1, 1, 6, 5));
    y.set_block_view(4, 4, a.view(1, 1, 6, 5));
    assert_eq!(x, y);
}

#[test]
fn prop_inplace_update_ops_bitmatch_copying_ops() {
    let b = 8usize;
    let n = 20usize;
    let r0 = Matrix::randn(b, b, 51).triu();
    let r1 = Matrix::randn(b, b, 52).triu();
    let (_y0, y1, t, _r) = tsqr_merge(&r0, &r1);
    let c0 = Matrix::randn(b, n, 53);
    let c1 = Matrix::randn(b, n, 54);

    // tree_update: full, into, and both halves agree bitwise.
    let st = tree_update(&c0, &c1, &y1, &t);
    let (mut i0, mut i1) = (c0.clone(), c1.clone());
    let w = tree_update_into(&mut i0, &mut i1, &y1, &t);
    assert_eq!(w, st.w);
    assert_eq!(i0, st.c0);
    assert_eq!(i1, st.c1);
    let mut top = c0.clone();
    assert_eq!(tree_update_half(&mut top, &c1, &y1, &t, true), st.w);
    assert_eq!(top, st.c0);
    let mut bot = c1.clone();
    assert_eq!(tree_update_half(&mut bot, &c0, &y1, &t, false), st.w);
    assert_eq!(bot, st.c1);

    // leaf_apply / recover wrappers vs in-place.
    let f = householder_qr(&Matrix::randn(24, b, 55));
    let c = Matrix::randn(24, n, 56);
    let want = leaf_apply(&f.y, &f.t, &c);
    let mut got = c.clone();
    leaf_apply_into(&f.y, &f.t, &mut got);
    assert_eq!(got, want);

    let rec_want = recover_block(&c1, &y1, &st.w);
    let mut rec_got = c1.clone();
    recover_block_into(&mut rec_got, &y1, &st.w);
    assert_eq!(rec_got, rec_want);
}

#[test]
fn prop_simd_levels_bitmatch_scalar_adversarial_shapes() {
    // (m, k, n) straddling every tile edge the micro-kernel cares about:
    // m % MR != 0, n % NR != 0, and k ∈ {1, KC, KC + 1} (KC = 256) so
    // the packed k-panel loop runs zero, one, and one-plus-a-remainder
    // full panels. Crossed with all four Trans combinations (distinct
    // packing paths) and a non-trivial alpha.
    let shapes = [
        (1usize, 1usize, 1usize),
        (5, 1, 17),
        (7, 256, 31),
        (13, 257, 47),
        (33, 100, 65),
        (64, 64, 64),
    ];
    let serial = ParCtx::serial();
    let mut seed = 9000u64;
    for &(m, k, n) in &shapes {
        for (ta, tb) in [
            (Trans::No, Trans::No),
            (Trans::Yes, Trans::No),
            (Trans::No, Trans::Yes),
            (Trans::Yes, Trans::Yes),
        ] {
            for alpha in [1.0f32, 0.37] {
                seed += 1;
                let (ar, ac) = if ta == Trans::No { (m, k) } else { (k, m) };
                let (br, bc) = if tb == Trans::No { (k, n) } else { (n, k) };
                let a = Matrix::randn(ar, ac, seed);
                let b = Matrix::randn(br, bc, seed + 5000);
                let want = gemm_with(&serial, SimdLevel::Scalar, ta, tb, alpha, &a, &b);
                for lvl in SimdLevel::available() {
                    let got = gemm_with(&serial, lvl, ta, tb, alpha, &a, &b);
                    assert_eq!(
                        got, want,
                        "({m},{k},{n}) {ta:?}/{tb:?} alpha={alpha}: level {} \
                         diverged bitwise from scalar",
                        lvl.name()
                    );
                }
            }
        }
    }
}

#[test]
fn prop_simd_levels_bitmatch_scalar_on_strided_views() {
    // Strided MatrixView sub-blocks: the packing loops see rows shorter
    // than the parent stride and ragged tile edges on both operands and
    // the accumulating destination.
    let big_a = Matrix::randn(40, 38, 61);
    let big_b = Matrix::randn(37, 36, 62);
    let big_c = Matrix::randn(42, 39, 63);
    let (m, k, n) = (19usize, 21usize, 18usize);
    let serial = ParCtx::serial();
    let run = |lvl: SimdLevel| {
        let mut c = big_c.clone();
        gemm_view_into_with(
            &serial,
            lvl,
            Trans::No,
            Trans::No,
            -0.5,
            big_a.view(3, 2, m, k),
            big_b.view(1, 4, k, n),
            1.0,
            c.view_mut(5, 3, m, n),
        );
        c
    };
    let want = run(SimdLevel::Scalar);
    for lvl in SimdLevel::available() {
        assert_eq!(
            run(lvl),
            want,
            "strided-view gemm at level {} diverged bitwise from scalar",
            lvl.name()
        );
    }
}

#[test]
fn prop_parallel_band_split_bitmatches_serial_at_any_width() {
    // 150 * 220 * 64 > PAR_MIN_WORK, so widths > 1 genuinely take the
    // banded path; every width must reproduce the serial product's bits
    // (each band runs the same macro-kernel over the same packed B).
    let a = Matrix::randn(150, 220, 71);
    let b = Matrix::randn(220, 64, 72);
    let want = gemm(Trans::No, Trans::No, 1.0, &a, &b);
    for width in [2usize, 3, 5, 8] {
        let got = gemm_with(
            &ParCtx::threads(width),
            SimdLevel::best(),
            Trans::No,
            Trans::No,
            1.0,
            &a,
            &b,
        );
        assert_eq!(got, want, "band width {width} diverged bitwise from serial");
    }
}

#[test]
fn prop_qr_par_bitmatches_serial() {
    // Tall panel so the blocked-QR trailing update crosses the parallel
    // work threshold: the factorization must be bit-identical at any
    // split width.
    let a = Matrix::randn(2048, 128, 81);
    let want = householder_qr(&a);
    for width in [2usize, 5] {
        let got = householder_qr_par(&ParCtx::threads(width), &a);
        assert_eq!(got.y, want.y, "width {width} y");
        assert_eq!(got.t, want.t, "width {width} t");
        assert_eq!(got.r, want.r, "width {width} r");
    }
}

#[test]
fn prop_random_shapes_qr_fuzz() {
    // Randomized sweep (deterministic seed): blocked QR vs reference on
    // shapes drawn around the sub-panel width.
    let mut rng = Rng64::new(2024);
    for _ in 0..12 {
        let b = 1 + rng.below(34);
        let m = b + rng.below(70);
        let a = Matrix::randn(m, b, rng.next_u64());
        let blk = householder_qr(&a);
        let refr = householder_qr_ref(&a);
        let err = rel_err(&blk.r, &refr.r);
        assert!(err < 5e-4, "({m},{b}): {err}");
        assert!(blk.t.is_upper_triangular(1e-6));
        assert!(blk.r.is_upper_triangular(0.0));
    }
}
