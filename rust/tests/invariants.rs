//! Property-based invariants (seeded-generator substitute for proptest,
//! which is unavailable in the offline crate set): hundreds of random
//! cases per property, deterministic via `Rng64`.

use ftcaqr::coordinator::tree::{
    exchange_pair, expected_redundancy, is_top, participation, reduce_active,
    reduce_pair, steps, Role,
};
use ftcaqr::linalg::{
    gemm, gram_residual, householder_qr, leaf_apply, recover_block, rel_err,
    tree_update, tsqr_merge, Matrix, Rng64, Trans,
};

const CASES: usize = 120;

/// Random (m, b) with m >= b, bounded sizes.
fn rand_panel_dims(rng: &mut Rng64) -> (usize, usize) {
    let b = [2, 4, 8, 16][rng.below(4)];
    let m = b * (1 + rng.below(8));
    (m, b)
}

#[test]
fn prop_reduce_pairing_is_perfect_matching_each_step() {
    // Every step's Upper/Lower pairs form a perfect matching of the
    // still-active indices (plus at most one promoted node).
    for q in 1..=64 {
        for s in 0..steps(q) {
            let mut seen = vec![false; q];
            let mut promoted = 0;
            for i in (0..q).filter(|i| reduce_active(*i, s)) {
                let (role, j) = reduce_pair(i, s, q);
                match role {
                    Role::Upper => {
                        assert!(!seen[i] && !seen[j], "q={q} s={s} i={i}");
                        assert_eq!(reduce_pair(j, s, q), (Role::Lower, i));
                        seen[i] = true;
                        seen[j] = true;
                    }
                    Role::Lower => {}
                    Role::Idle => promoted += 1,
                }
            }
            assert!(promoted <= 1, "q={q} s={s}: {promoted} promoted");
        }
    }
}

#[test]
fn prop_exchange_pairing_is_involution_and_covers_tree() {
    for q in 1..=64 {
        for s in 0..steps(q) {
            for i in 0..q {
                if let Some(j) = exchange_pair(i, s, q) {
                    assert_eq!(exchange_pair(j, s, q), Some(i));
                    assert!(is_top(i.min(j), i.max(j)));
                }
                if reduce_active(i, s) {
                    if let (Role::Upper | Role::Lower, j) = reduce_pair(i, s, q) {
                        assert_eq!(exchange_pair(i, s, q), Some(j));
                    }
                }
            }
        }
    }
}

#[test]
fn prop_participation_terminates_and_root_survives() {
    for q in 1..=64 {
        for i in 0..q {
            let p = participation(i, q);
            assert!(p.len() <= steps(q));
            if i == 0 {
                assert!(p.iter().all(|(_, r, _)| *r == Role::Upper));
            } else {
                assert_eq!(
                    p.iter().filter(|(_, r, _)| *r == Role::Lower).count(),
                    1,
                    "i={i} q={q}"
                );
            }
        }
    }
}

#[test]
fn prop_redundancy_formula() {
    for s in 0..8 {
        assert_eq!(expected_redundancy(s), 2usize << s);
    }
}

#[test]
fn prop_qr_gram_invariant() {
    let mut rng = Rng64::new(1001);
    for case in 0..CASES {
        let (m, b) = rand_panel_dims(&mut rng);
        let a = Matrix::randn(m, b, rng.next_u64());
        let f = householder_qr(&a);
        assert!(
            gram_residual(&a, &f.r) < 5e-3,
            "case {case}: m={m} b={b} residual {}",
            gram_residual(&a, &f.r)
        );
        assert!(f.r.is_upper_triangular(0.0));
        assert!(f.t.is_upper_triangular(1e-6));
    }
}

#[test]
fn prop_zero_row_padding_exact() {
    let mut rng = Rng64::new(2002);
    for _ in 0..CASES {
        let (m, b) = rand_panel_dims(&mut rng);
        let pad = rng.below(3) * b;
        let a = Matrix::randn(m, b, rng.next_u64());
        let f1 = householder_qr(&a);
        let f2 = householder_qr(&a.pad_to(m + pad, b));
        assert!(rel_err(&f2.r, &f1.r) < 1e-4);
        assert!(rel_err(&f2.t, &f1.t) < 1e-4);
        if pad > 0 {
            assert_eq!(f2.y.block(m, 0, pad, b).fro_norm(), 0.0);
        }
    }
}

#[test]
fn prop_zero_col_padding_exact_for_updates() {
    let mut rng = Rng64::new(3003);
    for _ in 0..CASES {
        let (m, b) = rand_panel_dims(&mut rng);
        let n = b * (1 + rng.below(4));
        let pad = rng.below(3) * b;
        let f = householder_qr(&Matrix::randn(m, b, rng.next_u64()));
        let c = Matrix::randn(m, n, rng.next_u64());
        let want = leaf_apply(&f.y, &f.t, &c);
        let got = leaf_apply(&f.y, &f.t, &c.pad_to(m, n + pad)).crop_to(m, n);
        assert!(rel_err(&got, &want) < 1e-4);
    }
}

#[test]
fn prop_tree_update_equals_stacked_apply() {
    let mut rng = Rng64::new(4004);
    for _ in 0..CASES {
        let b = [2, 4, 8][rng.below(3)];
        let n = b * (1 + rng.below(6));
        let r0 = Matrix::randn(b, b, rng.next_u64()).triu();
        let r1 = Matrix::randn(b, b, rng.next_u64()).triu();
        let (y0, y1, t, _r) = tsqr_merge(&r0, &r1);
        assert!(rel_err(&y0, &Matrix::eye(b)) < 1e-5, "Y0 must be I");
        let c0 = Matrix::randn(b, n, rng.next_u64());
        let c1 = Matrix::randn(b, n, rng.next_u64());
        let st = tree_update(&c0, &c1, &y1, &t);
        let full = leaf_apply(&y0.vstack(&y1), &t, &c0.vstack(&c1));
        assert!(rel_err(&st.c0, &full.block(0, 0, b, n)) < 2e-4);
        assert!(rel_err(&st.c1, &full.block(b, 0, b, n)) < 2e-4);
    }
}

#[test]
fn prop_recovery_identity() {
    // Paper III-C: both members of a pair are recomputable from
    // (C', Y, W) — for every random instance.
    let mut rng = Rng64::new(5005);
    for _ in 0..CASES {
        let b = [2, 4, 8, 16][rng.below(4)];
        let n = b * (1 + rng.below(6));
        let r0 = Matrix::randn(b, b, rng.next_u64()).triu();
        let r1 = Matrix::randn(b, b, rng.next_u64()).triu();
        let (_y0, y1, t, _r) = tsqr_merge(&r0, &r1);
        let c0 = Matrix::randn(b, n, rng.next_u64());
        let c1 = Matrix::randn(b, n, rng.next_u64());
        let st = tree_update(&c0, &c1, &y1, &t);
        let rec0 = recover_block(&c0, &Matrix::eye(b), &st.w);
        let rec1 = recover_block(&c1, &y1, &st.w);
        assert!(rel_err(&rec0, &st.c0) < 1e-5);
        assert!(rel_err(&rec1, &st.c1) < 1e-5);
    }
}

#[test]
fn prop_gemm_transpose_consistency() {
    let mut rng = Rng64::new(6006);
    for _ in 0..CASES {
        let m = 1 + rng.below(12);
        let k = 1 + rng.below(12);
        let n = 1 + rng.below(12);
        let a = Matrix::randn(m, k, rng.next_u64());
        let b = Matrix::randn(k, n, rng.next_u64());
        let c1 = gemm(Trans::No, Trans::No, 1.0, &a, &b);
        // Aᵀ flagged transposed == A plain.
        let c2 = gemm(Trans::Yes, Trans::No, 1.0, &a.transpose(), &b);
        // Bᵀ flagged transposed == B plain.
        let c3 = gemm(Trans::No, Trans::Yes, 1.0, &a, &b.transpose());
        assert!(rel_err(&c2, &c1) < 1e-4);
        assert!(rel_err(&c3, &c1) < 1e-4);
    }
}

#[test]
fn prop_caqr_random_configs() {
    // End-to-end random configuration fuzz (native backend).
    use ftcaqr::config::{Algorithm, RunConfig};
    use ftcaqr::coordinator::run_caqr_simple;
    let mut rng = Rng64::new(7007);
    for case in 0..24 {
        let b = [8, 16][rng.below(2)];
        let mult = 1 + rng.below(3); // local rows = mult * b
        let procs = 1 + rng.below(6);
        let panels = 1 + rng.below(4);
        let cfg = RunConfig {
            rows: procs * mult * b,
            cols: panels * b,
            block: b,
            procs,
            algorithm: if rng.chance(0.5) {
                Algorithm::Plain
            } else {
                Algorithm::FaultTolerant
            },
            seed: rng.next_u64(),
            ..Default::default()
        };
        if cfg.validate().is_err() {
            continue; // e.g. cols > rows
        }
        let out = run_caqr_simple(cfg.clone()).unwrap();
        let res = out.residual.unwrap();
        assert!(
            res < 1e-3,
            "case {case} cfg {}x{} b{} p{}: residual {res}",
            cfg.rows,
            cfg.cols,
            cfg.block,
            cfg.procs
        );
    }
}
