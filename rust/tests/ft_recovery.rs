//! Integration: failure injection + REBUILD recovery (paper §III-C, E3).
//!
//! Every test kills one or more ranks mid-factorization and checks that
//! the recovered run produces *exactly* the factorization of the
//! failure-free run — the strongest form of the paper's recovery claim.

use ftcaqr::backend::Backend;
use ftcaqr::config::{Algorithm, RunConfig};
use ftcaqr::coordinator::run_caqr_matrix;
use ftcaqr::fault::{FaultPlan, FaultSpec, Phase, ScheduledKill};
use ftcaqr::ft::Semantics;
use ftcaqr::linalg::Matrix;
use ftcaqr::trace::Trace;

fn cfg(procs: usize) -> RunConfig {
    RunConfig {
        rows: procs * 128,
        cols: 128,
        block: 32,
        procs,
        algorithm: Algorithm::FaultTolerant,
        semantics: Semantics::Rebuild,
        ..Default::default()
    }
}

fn kill(rank: usize, panel: usize, step: usize, phase: Phase) -> ScheduledKill {
    ScheduledKill::new(rank, panel, step, phase)
}

fn run_with(c: &RunConfig, a: &Matrix, kills: Vec<ScheduledKill>) -> ftcaqr::coordinator::CaqrOutcome {
    let fault = if kills.is_empty() {
        FaultPlan::none()
    } else {
        FaultPlan::new(FaultSpec::Schedule { kills })
    };
    run_caqr_matrix(c.clone(), a.clone(), Backend::native(), fault, Trace::disabled())
        .unwrap()
}

#[test]
fn recovery_reproduces_failure_free_result_update_phase() {
    let c = cfg(4);
    let a = Matrix::randn(c.rows, c.cols, 3);
    let clean = run_with(&c, &a, vec![]);
    let failed = run_with(&c, &a, vec![kill(2, 1, 0, Phase::Update)]);
    assert_eq!(failed.report.failures, 1);
    assert_eq!(failed.report.recoveries, 1);
    // Bitwise-identical R: recovery recomputed exactly the same state.
    assert_eq!(clean.r, failed.r);
    assert_eq!(clean.reduced, failed.reduced);
}

#[test]
fn recovery_reproduces_failure_free_result_tsqr_phase() {
    let c = cfg(4);
    let a = Matrix::randn(c.rows, c.cols, 5);
    let clean = run_with(&c, &a, vec![]);
    let failed = run_with(&c, &a, vec![kill(1, 2, 1, Phase::Tsqr)]);
    assert_eq!(failed.report.failures, 1);
    assert_eq!(failed.report.recoveries, 1);
    assert_eq!(clean.r, failed.r);
}

#[test]
fn every_rank_recoverable_at_first_update_step() {
    let c = cfg(4);
    let a = Matrix::randn(c.rows, c.cols, 7);
    let clean = run_with(&c, &a, vec![]);
    for victim in 0..4 {
        let failed = run_with(&c, &a, vec![kill(victim, 0, 0, Phase::Update)]);
        assert_eq!(failed.report.failures, 1, "victim {victim}");
        assert_eq!(clean.r, failed.r, "victim {victim}");
    }
}

#[test]
fn multiple_failures_across_panels() {
    let c = cfg(8);
    let a = Matrix::randn(c.rows, c.cols, 11);
    let clean = run_with(&c, &a, vec![]);
    let failed = run_with(
        &c,
        &a,
        vec![
            kill(2, 0, 0, Phase::Update),
            kill(5, 1, 0, Phase::Update),
            kill(6, 2, 1, Phase::Tsqr),
        ],
    );
    assert_eq!(failed.report.failures, 3);
    assert_eq!(failed.report.recoveries, 3);
    assert_eq!(clean.r, failed.r);
}

#[test]
fn same_rank_fails_twice() {
    let c = cfg(4);
    let a = Matrix::randn(c.rows, c.cols, 13);
    let clean = run_with(&c, &a, vec![]);
    let failed = run_with(
        &c,
        &a,
        vec![kill(2, 0, 0, Phase::Update), kill(2, 2, 0, Phase::Update)],
    );
    // The FaultPlan's once-flags are per scheduled kill, so the rebuilt
    // rank survives panel 0 and dies again at panel 2. Only the FINAL
    // incarnation completes its replay, so one recovery is recorded.
    assert_eq!(failed.report.failures, 2);
    assert_eq!(failed.report.recoveries, 1);
    assert_eq!(clean.r, failed.r);
}

#[test]
fn random_failures_with_budget() {
    let c = cfg(8);
    let a = Matrix::randn(c.rows, c.cols, 17);
    let clean = run_with(&c, &a, vec![]);
    let fault = FaultPlan::new(FaultSpec::Random { prob: 0.05, seed: 9, max_failures: 3 });
    let failed = run_caqr_matrix(
        c.clone(),
        a.clone(),
        Backend::native(),
        fault,
        Trace::disabled(),
    )
    .unwrap();
    // Every completed replacement records one recovery; a replacement
    // that itself dies again is recovered by the next incarnation, so
    // recoveries <= failures with at least one of each for this seed.
    assert!(failed.report.failures >= 1, "seed should trigger failures");
    assert!(failed.report.recoveries >= 1);
    assert!(failed.report.recoveries <= failed.report.failures);
    assert_eq!(clean.r, failed.r);
}

#[test]
fn recovery_charges_communication_and_fetches_from_one_buddy_per_step() {
    let c = cfg(4);
    let a = Matrix::randn(c.rows, c.cols, 19);
    let trace = Trace::new();
    let fault = FaultPlan::new(FaultSpec::Schedule {
        kills: vec![kill(2, 2, 0, Phase::Update)],
    });
    let out = run_caqr_matrix(c.clone(), a, Backend::native(), fault, trace.clone()).unwrap();
    assert_eq!(out.report.recoveries, 1);
    let fetches = trace.of_kind("recovery_fetch");
    assert!(!fetches.is_empty(), "replay must fetch retained state");
    // Paper C2: each fetched step comes from exactly ONE process.
    for f in &fetches {
        assert_eq!(f.rank, 2, "only the rebuilt rank fetches");
    }
    // Replay covers all panels before the failure point.
    let panels: std::collections::HashSet<usize> =
        fetches.iter().map(|e| e.panel).collect();
    assert!(panels.contains(&0) && panels.contains(&1));
}

#[test]
fn abort_semantics_fails_the_run() {
    let mut c = cfg(4);
    c.semantics = Semantics::Abort;
    let a = Matrix::randn(c.rows, c.cols, 23);
    let fault = FaultPlan::new(FaultSpec::Schedule {
        kills: vec![kill(2, 1, 0, Phase::Update)],
    });
    let res = run_caqr_matrix(c, a, Backend::native(), fault, Trace::disabled());
    assert!(res.is_err(), "Abort semantics must fail the run");
}

#[test]
fn plain_algorithm_cannot_recover() {
    let mut c = cfg(4);
    c.algorithm = Algorithm::Plain;
    c.semantics = Semantics::Abort;
    let a = Matrix::randn(c.rows, c.cols, 29);
    let fault = FaultPlan::new(FaultSpec::Schedule {
        kills: vec![kill(2, 1, 0, Phase::Update)],
    });
    let res = run_caqr_matrix(c, a, Backend::native(), fault, Trace::disabled());
    assert!(res.is_err(), "plain CAQR has no redundancy to recover from");
}

#[test]
fn recovery_time_grows_with_failure_panel() {
    // E3's shape: replay cost grows with how late the failure happens.
    let c = cfg(4);
    let a = Matrix::randn(c.rows, c.cols, 31);
    // (The last panel has no trailing update, so sweep 0..=2.)
    let mut cps = Vec::new();
    for panel in [0, 1, 2] {
        let failed = run_with(&c, &a, vec![kill(2, panel, 0, Phase::Update)]);
        assert_eq!(failed.report.recoveries, 1, "panel {panel}");
        cps.push(failed.report.critical_path);
    }
    // Later failures should not be cheaper than the earliest failure.
    assert!(
        cps[2] >= cps[0],
        "recovery at panel 2 ({}) should cost at least panel 0 ({})",
        cps[2],
        cps[0]
    );
}

#[test]
fn store_memory_bounded_by_history() {
    let c = cfg(4);
    let a = Matrix::randn(c.rows, c.cols, 37);
    let out = run_with(&c, &a, vec![]);
    // Retained state exists (FT mode) and is far smaller than P full
    // matrix copies (the diskless-checkpoint cost).
    assert!(out.store_peak_bytes > 0);
    // The FT scheme retains per-step factors for the whole history; it
    // trades memory for rollback-free recovery. Bound: a small constant
    // times the input matrix (one diskless checkpoint costs 1x).
    let full_copies = (c.rows * c.cols * 4) as u64;
    assert!(
        out.store_peak_bytes < 8 * full_copies,
        "retained {} >= 8x checkpoint {}",
        out.store_peak_bytes,
        full_copies
    );
}
