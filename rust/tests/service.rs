//! Service-level integration: many concurrent tenants on one pool.
//!
//! The contract under test (ISSUE 4 acceptance):
//! * >= 32 concurrent jobs of mixed CAQR and TSQR shapes, with faults
//!   injected into a subset, run on a pool far narrower than the total
//!   simulated rank count — and every job's factor output is **bitwise
//!   identical** to the same job run alone;
//! * a job poisoned by a correlated buddy-pair kill fails individually
//!   with `Fail::Unrecoverable` while its neighbors complete;
//! * per-job metrics are isolated: a job's message/byte/flop counts are
//!   the same whether it runs concurrently or serially;
//! * the batched TSQR lane amortizes message counts without changing
//!   any job's result.

use ftcaqr::backend::Backend;
use ftcaqr::config::RunConfig;
use ftcaqr::coordinator::{run_caqr, run_tsqr_pooled, TsqrMode};
use ftcaqr::fault::{FaultPlan, Phase, ScheduledKill};
use ftcaqr::ft::Fail;
use ftcaqr::linalg::Matrix;
use ftcaqr::service::{seed_for, JobOutput, JobSpec, Service, ServiceConfig};
use ftcaqr::sim::CostModel;
use ftcaqr::trace::Trace;

fn caqr_spec(procs: usize, cols: usize, seed: u64, kills: Vec<ScheduledKill>) -> JobSpec {
    JobSpec::Caqr {
        cfg: RunConfig {
            rows: procs * 32,
            cols,
            block: 16,
            procs,
            seed,
            verify: false,
            ..Default::default()
        },
        kills,
    }
}

fn tsqr_spec(procs: usize, seed: u64) -> JobSpec {
    JobSpec::Tsqr { rows: procs * 8, block: 8, procs, mode: TsqrMode::FaultTolerant, seed }
}

/// Run the same job alone (its own private pool) and return its factors.
fn solo_factors(spec: &JobSpec) -> (Matrix, ftcaqr::metrics::Report) {
    match spec {
        JobSpec::Caqr { cfg, kills } => {
            let fault = if kills.is_empty() {
                FaultPlan::none()
            } else {
                FaultPlan::schedule(kills.clone())
            };
            let out =
                run_caqr(cfg.clone(), Backend::native(), fault, Trace::disabled())
                    .expect("solo caqr");
            (out.r, out.report)
        }
        JobSpec::Tsqr { rows, block, procs, mode, seed } => {
            let a = Matrix::randn(*rows, *block, *seed);
            let out = run_tsqr_pooled(
                &a,
                *procs,
                *mode,
                Backend::native(),
                CostModel::default(),
                2,
            )
            .expect("solo tsqr");
            (out.r, out.report)
        }
    }
}

fn job_r(output: &JobOutput) -> &Matrix {
    match output {
        JobOutput::Caqr(out) => &out.r,
        JobOutput::Tsqr { r, .. } => r,
    }
}

#[test]
fn thirty_two_concurrent_mixed_jobs_match_solo_bitwise() {
    // 33 jobs, three shapes, faults in every sixth job; the pool is 4
    // threads wide while the workload simulates ~230 ranks in total.
    let specs: Vec<JobSpec> = (0..33u64)
        .map(|i| {
            let seed = seed_for(7, i);
            let kills = if i % 6 == 0 {
                vec![ScheduledKill::new(1, 0, 0, Phase::Update)]
            } else {
                Vec::new()
            };
            match i % 3 {
                0 => caqr_spec(4, 32, seed, kills),
                1 => caqr_spec(8, 64, seed, kills),
                _ => tsqr_spec(16, seed),
            }
        })
        .collect();
    let total_ranks: usize = specs.iter().map(|s| s.procs()).sum();
    let workers = 4;
    assert!(workers * 8 < total_ranks, "pool must be << total simulated ranks");

    let svc = Service::new(ServiceConfig {
        workers,
        max_inflight_ranks: 48,
        batch_max: 4,
    });
    let handles = svc.submit_all(specs.clone()).unwrap();
    let outcomes: Vec<_> = handles.into_iter().map(|h| h.wait()).collect();

    for (i, (spec, outcome)) in specs.iter().zip(&outcomes).enumerate() {
        let output = outcome
            .output
            .as_ref()
            .unwrap_or_else(|e| panic!("job {i} failed: {e:?}"));
        match spec {
            JobSpec::Caqr { cfg, kills } => {
                // The reduced matrix too, not just R — and failure
                // accounting stays per-job.
                let JobOutput::Caqr(out) = output else { panic!("job {i}: caqr expected") };
                let fault = if kills.is_empty() {
                    FaultPlan::none()
                } else {
                    FaultPlan::schedule(kills.clone())
                };
                let solo =
                    run_caqr(cfg.clone(), Backend::native(), fault, Trace::disabled())
                        .unwrap();
                assert_eq!(out.r, solo.r, "job {i}: R must be bitwise-identical");
                assert_eq!(out.reduced, solo.reduced, "job {i}");
                assert_eq!(out.report.failures, kills.len() as u64, "job {i}");
            }
            JobSpec::Tsqr { .. } => {
                let (solo_r, _) = solo_factors(spec);
                assert_eq!(
                    job_r(output),
                    &solo_r,
                    "job {i}: factors must be bitwise-identical"
                );
            }
        }
    }
    let totals = svc.totals();
    assert_eq!(totals.jobs_ok, 33);
    assert_eq!(totals.jobs_failed, 0);
    // Faulted jobs recovered (6 faulted CAQR jobs: i = 0,6,12,18,24,30).
    assert_eq!(totals.report.failures, 6);
    assert_eq!(totals.report.recoveries, 6);
}

#[test]
fn poisoned_job_fails_alone_with_unrecoverable() {
    // Job 1 gets a correlated buddy-pair kill at a step whose retained
    // redundancy both victims hold: unrecoverable by the single-buddy
    // protocol. Its neighbors (including a faulted-but-recoverable job)
    // must complete untouched.
    let pair = vec![
        ScheduledKill::new(2, 0, 1, Phase::Tsqr).in_group(0),
        ScheduledKill::new(3, 0, 1, Phase::Tsqr).in_group(0),
    ];
    let specs = vec![
        caqr_spec(4, 64, seed_for(11, 0), Vec::new()),
        JobSpec::Caqr {
            cfg: RunConfig {
                rows: 256,
                cols: 64,
                block: 16,
                procs: 4,
                seed: seed_for(11, 1),
                verify: false,
                ..Default::default()
            },
            kills: pair,
        },
        caqr_spec(8, 64, seed_for(11, 2), vec![ScheduledKill::new(1, 0, 0, Phase::Update)]),
        tsqr_spec(8, seed_for(11, 3)),
    ];
    let svc = Service::new(ServiceConfig {
        workers: 3,
        max_inflight_ranks: 64,
        batch_max: 1,
    });
    let outcomes: Vec<_> =
        svc.submit_all(specs).unwrap().into_iter().map(|h| h.wait()).collect();

    let poisoned = &outcomes[1];
    let err = poisoned.output.as_ref().expect_err("buddy-pair kill must poison the job");
    assert!(
        matches!(err.fail, Some(Fail::Unrecoverable { .. })),
        "expected Unrecoverable, got {:?}",
        err.fail
    );
    assert!(poisoned.unrecoverable());
    assert!(err.message.contains("unrecoverable"), "{}", err.message);
    for (i, o) in outcomes.iter().enumerate() {
        if i != 1 {
            assert!(o.output.is_ok(), "job {i} must be unaffected: {:?}", o.output);
        }
    }
    let totals = svc.totals();
    assert_eq!(totals.jobs_ok, 3);
    assert_eq!(totals.jobs_failed, 1);
}

#[test]
fn per_job_metrics_are_isolated_under_concurrency() {
    // Failure-free jobs report exactly the same per-job message/byte/
    // flop counts whether they share the pool with five neighbors or run
    // alone on a private pool.
    let specs: Vec<JobSpec> = (0..6u64)
        .map(|i| match i % 3 {
            0 => caqr_spec(4, 32, seed_for(23, i), Vec::new()),
            1 => caqr_spec(8, 64, seed_for(23, i), Vec::new()),
            _ => tsqr_spec(8, seed_for(23, i)),
        })
        .collect();
    let svc = Service::new(ServiceConfig {
        workers: 4,
        max_inflight_ranks: 0,
        batch_max: 1, // unbatched so every job has its own world/report
    });
    let outcomes: Vec<_> =
        svc.submit_all(specs.clone()).unwrap().into_iter().map(|h| h.wait()).collect();
    for (i, (spec, o)) in specs.iter().zip(&outcomes).enumerate() {
        assert!(o.output.is_ok(), "job {i}: {:?}", o.output);
        let (_, solo_report) = solo_factors(spec);
        assert_eq!(o.report.messages, solo_report.messages, "job {i} messages");
        assert_eq!(o.report.exchanges, solo_report.exchanges, "job {i} exchanges");
        assert_eq!(o.report.bytes, solo_report.bytes, "job {i} bytes");
        assert_eq!(o.report.flops, solo_report.flops, "job {i} flops");
    }
    // And the service totals are exactly the sum of the per-job reports.
    let totals = svc.totals();
    let sum_msgs: u64 = outcomes.iter().map(|o| o.report.messages).sum();
    let sum_bytes: u64 = outcomes.iter().map(|o| o.report.bytes).sum();
    assert_eq!(totals.report.messages, sum_msgs);
    assert_eq!(totals.report.bytes, sum_bytes);
}

#[test]
fn batched_lane_amortizes_without_changing_results() {
    let k = 8u64;
    let specs: Vec<JobSpec> = (0..k).map(|i| tsqr_spec(16, seed_for(31, i))).collect();
    let svc = Service::new(ServiceConfig {
        workers: 4,
        max_inflight_ranks: 0,
        batch_max: k as usize,
    });
    let outcomes: Vec<_> =
        svc.submit_all(specs.clone()).unwrap().into_iter().map(|h| h.wait()).collect();
    let mut batch_sizes = Vec::new();
    for (i, (spec, o)) in specs.iter().zip(&outcomes).enumerate() {
        let output = o.output.as_ref().unwrap_or_else(|e| panic!("job {i}: {e:?}"));
        let JobOutput::Tsqr { r, batch_size } = output else { panic!("tsqr expected") };
        let (solo_r, _) = solo_factors(spec);
        assert_eq!(r, &solo_r, "job {i}: batched R must equal solo R bitwise");
        batch_sizes.push(*batch_size);
    }
    // The whole burst rode one sweep...
    assert!(batch_sizes.iter().all(|&b| b == k as usize), "{batch_sizes:?}");
    // ...so the exchange count is one sweep's worth, not k sweeps'.
    let (_, solo_report) = solo_factors(&specs[0]);
    assert_eq!(svc.totals().report.exchanges, solo_report.exchanges);
}

#[test]
fn admission_cap_narrower_than_workload_still_completes_fifo() {
    // Cap of 8 in-flight ranks with 8-rank jobs: strictly one at a time,
    // plus a 16-rank job wider than the cap that must run (alone) rather
    // than starve.
    let specs = vec![
        caqr_spec(8, 32, seed_for(41, 0), Vec::new()),
        tsqr_spec(16, seed_for(41, 1)), // wider than the cap
        caqr_spec(8, 32, seed_for(41, 2), Vec::new()),
    ];
    let svc = Service::new(ServiceConfig {
        workers: 2,
        max_inflight_ranks: 8,
        batch_max: 1,
    });
    let outcomes: Vec<_> =
        svc.submit_all(specs).unwrap().into_iter().map(|h| h.wait()).collect();
    assert!(outcomes.iter().all(|o| o.output.is_ok()));
    assert_eq!(svc.totals().jobs_ok, 3);
    let stats = svc.queue_stats();
    assert_eq!((stats.pending, stats.inflight_jobs, stats.inflight_ranks), (0, 0, 0));
}
