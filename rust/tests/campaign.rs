//! Integration: the stochastic-campaign subsystem as a randomized soak
//! harness for the recovery protocol.
//!
//! * worker-width invariance: a seeded failure model must produce the
//!   same kill schedule (and the same factors) no matter how many pool
//!   workers drive the simulated ranks — `StochasticSpec` because it
//!   compiles to a schedule before any rank runs, `FaultSpec::Random`
//!   because its coins are a pure function of `(rank, incarnation,
//!   site, seed)`;
//! * store retention edges under randomized kills: a seeded fuzz loop
//!   drives `RecoveryStore` against a plain model map and checks that
//!   the progress frontier matches and that no stale lane is ever
//!   resurrected after a REBUILD;
//! * straggler injection end to end: a 10x-slowed rank (plus a kill)
//!   still completes with bitwise-identical factors, paying only
//!   logical time;
//! * campaign reproducibility through the public API.

use std::collections::{HashMap, HashSet};
use std::sync::Arc;

use ftcaqr::backend::Backend;
use ftcaqr::campaign::{run_campaign, CampaignConfig, IntervalChoice};
use ftcaqr::config::{Algorithm, RunConfig};
use ftcaqr::coordinator::{run_caqr_matrix, CaqrOutcome, RecoveryStore, Retained};
use ftcaqr::fault::{FaultPlan, FaultSpec, Hazard, Phase, StochasticSpec};
use ftcaqr::ft::Semantics;
use ftcaqr::linalg::{Matrix, Rng64};
use ftcaqr::metrics::json::JsonSink;
use ftcaqr::trace::Trace;

fn cfg(procs: usize, workers: usize) -> RunConfig {
    RunConfig {
        rows: procs * 64,
        cols: 64,
        block: 16,
        procs,
        workers,
        algorithm: Algorithm::FaultTolerant,
        semantics: Semantics::Rebuild,
        ..Default::default()
    }
}

fn run_with(
    c: &RunConfig,
    a: &Matrix,
    fault: Arc<FaultPlan>,
) -> anyhow::Result<CaqrOutcome> {
    run_caqr_matrix(c.clone(), a.clone(), Backend::native(), fault, Trace::disabled())
}

/// The two runs must be indistinguishable: same success/failure, and on
/// success the same factors and the same injected-failure count.
fn assert_outcomes_agree(
    a: &anyhow::Result<CaqrOutcome>,
    b: &anyhow::Result<CaqrOutcome>,
    what: &str,
) {
    match (a, b) {
        (Ok(x), Ok(y)) => {
            assert_eq!(x.r, y.r, "{what}: R differs");
            assert_eq!(x.reduced, y.reduced, "{what}: reduced factor differs");
            assert_eq!(x.report.failures, y.report.failures, "{what}: failure count differs");
            assert_eq!(
                x.report.recoveries, y.report.recoveries,
                "{what}: recovery count differs"
            );
        }
        (Err(x), Err(y)) => {
            assert_eq!(format!("{x:#}"), format!("{y:#}"), "{what}: errors differ");
        }
        (x, y) => panic!(
            "{what}: outcomes diverge: {:?} vs {:?}",
            x.as_ref().map(|_| "ok").map_err(|e| format!("{e:#}")),
            y.as_ref().map(|_| "ok").map_err(|e| format!("{e:#}"))
        ),
    }
}

#[test]
fn stochastic_schedule_is_identical_across_worker_widths() {
    // The generator compiles to a schedule before any rank runs, so the
    // schedule cannot depend on pool width — and with one kill the run
    // must recover to bitwise-identical factors at every width.
    let procs = 4;
    let spec = StochasticSpec {
        hazard: Hazard::Poisson,
        mtbf_panels: 1.0, // hot process: a kill is all but certain
        node_width: 1,
        max_failures: 1,
        seed: 2024,
    };
    let c1 = cfg(procs, 1);
    let kills = spec.kills(procs, c1.panels());
    assert_eq!(kills, spec.kills(procs, c1.panels()), "generator must be pure");
    assert!(kills.len() <= 1);

    let a = Matrix::randn(c1.rows, c1.cols, 71);
    let clean = run_with(&c1, &a, FaultPlan::none()).unwrap();
    let mut outcomes = Vec::new();
    for workers in [1usize, 4] {
        let c = cfg(procs, workers);
        let out = run_with(&c, &a, FaultPlan::new(spec.fault_spec(procs, c.panels())));
        outcomes.push(out);
    }
    assert_outcomes_agree(&outcomes[0], &outcomes[1], "stochastic schedule");
    let out = outcomes[0].as_ref().expect("single stochastic kill must be recoverable");
    // <= rather than ==: a kill can land on a site the run never visits
    // (e.g. the last panel's update phase, which has no trailing matrix).
    assert!(out.report.failures as usize <= kills.len());
    assert_eq!(out.report.recoveries, out.report.failures);
    assert_eq!(clean.r, out.r, "recovered factors must match the clean run");
}

#[test]
fn random_fault_coins_are_identical_across_worker_widths() {
    // FaultSpec::Random draws one deterministic coin per (rank,
    // incarnation, site, seed). With a budget wide enough that the cap
    // never arbitrates between concurrent winners, the fired set — and
    // hence the whole run — is a pure function of the seed, not of the
    // pool width.
    let procs = 4;
    let a = Matrix::randn(procs * 64, 64, 73);
    let mk = || {
        FaultPlan::new(FaultSpec::Random { prob: 0.02, seed: 90210, max_failures: 100 })
    };
    let r1 = run_with(&cfg(procs, 1), &a, mk());
    let r4 = run_with(&cfg(procs, 4), &a, mk());
    assert_outcomes_agree(&r1, &r4, "random coins");
}

#[test]
fn straggler_run_completes_with_identical_factors() {
    // Satellite: a 10x straggler is slow, not dead. The run completes
    // (no stall misclassification), the factors are bitwise identical —
    // slowness only exists on the logical time axis — and the critical
    // path stretches. Also holds with a kill in flight: recovery and
    // straggling compose.
    let procs = 4;
    let base = cfg(procs, 1);
    let a = Matrix::randn(base.rows, base.cols, 79);
    // Fresh plan per run: scheduled kills fire once per FaultPlan.
    let kill =
        || FaultPlan::schedule(vec![ftcaqr::fault::ScheduledKill::new(2, 1, 0, Phase::Update)]);

    let healthy = run_with(&base, &a, kill()).unwrap();
    let mut slowed_cfg = base.clone();
    slowed_cfg.stragglers = vec![(1, 10.0)];
    let slowed = run_with(&slowed_cfg, &a, kill()).unwrap();

    assert_eq!(healthy.report.failures, 1);
    assert_eq!(slowed.report.failures, 1);
    assert_eq!(slowed.report.recoveries, 1, "straggler must not break recovery");
    assert_eq!(healthy.r, slowed.r, "straggling must not change the arithmetic");
    assert_eq!(healthy.reduced, slowed.reduced);
    assert!(
        slowed.report.critical_path > healthy.report.critical_path,
        "10x straggler must lengthen the critical path: {} vs {}",
        slowed.report.critical_path,
        healthy.report.critical_path
    );
}

// ---------------------------------------------------------------------
// RecoveryStore fuzz (satellite: retention edges under randomized kills)
// ---------------------------------------------------------------------

/// The in-panel site order [`RecoveryStore`] documents: TSQR steps
/// first, then update lanes ascending, steps innermost.
fn site_index(phase: Phase, step: usize, lane: u32) -> u64 {
    match phase {
        Phase::Tsqr => step as u64,
        Phase::Update => (1u64 << 40) | ((lane as u64) << 20) | (step as u64),
    }
}

fn retained() -> Retained {
    Retained {
        buddy: 0,
        w: Arc::new(Matrix::zeros(4, 2)),
        y1: Arc::new(Matrix::zeros(2, 2)),
        t: Arc::new(Matrix::zeros(2, 2)),
        r_merged: Arc::new(Matrix::zeros(2, 2)),
    }
}

#[test]
fn store_retention_fuzz_never_resurrects_stale_lanes() {
    const RANKS: usize = 4;
    const PANELS: usize = 4;
    const STEPS: usize = 3;
    const LANES: u32 = 3;
    const ITERS: usize = 1000;

    let store = RecoveryStore::new();
    let entry_bytes = retained().nbytes() as u64;
    let mut rng = Rng64::new(0xF0CC);

    // The model: plain maps the store must agree with at every step.
    let mut live: HashMap<(usize, usize, Phase, usize, u32), ()> = HashMap::new();
    let mut frontier: HashMap<(usize, usize), u64> = HashMap::new(); // (rank, panel) -> max site
    let mut inc = [0u32; RANKS];
    let mut died = [false; RANKS];
    let mut touched: HashSet<(usize, usize, Phase, usize, u32)> = HashSet::new();

    let pick_key = |rng: &mut Rng64| {
        let rank = (rng.next_u64() % RANKS as u64) as usize;
        let panel = (rng.next_u64() % PANELS as u64) as usize;
        let phase = if rng.next_u64() % 2 == 0 { Phase::Tsqr } else { Phase::Update };
        let step = (rng.next_u64() % STEPS as u64) as usize;
        let lane = if phase == Phase::Tsqr { 0 } else { (rng.next_u64() % LANES as u64) as u32 };
        (rank, panel, phase, step, lane)
    };

    for iter in 0..ITERS {
        match rng.next_u64() % 100 {
            // Live insert by the rank's current incarnation.
            0..=59 => {
                let (rank, panel, phase, step, lane) = pick_key(&mut rng);
                store.insert(rank, inc[rank], panel, phase, step, lane, retained());
                live.insert((rank, panel, phase, step, lane), ());
                touched.insert((rank, panel, phase, step, lane));
                let f = frontier.entry((rank, panel)).or_insert(0);
                *f = (*f).max(site_index(phase, step, lane));
            }
            // Straggling insert from a DEAD incarnation: the store must
            // reject the entry (never resurrect memory that died with
            // the process) while still advancing the frontier.
            60..=74 => {
                let (rank, panel, phase, step, lane) = pick_key(&mut rng);
                if !died[rank] {
                    continue;
                }
                let stale_inc = inc[rank] - 1;
                let existed = live.contains_key(&(rank, panel, phase, step, lane));
                store.insert(rank, stale_inc, panel, phase, step, lane, retained());
                touched.insert((rank, panel, phase, step, lane));
                let f = frontier.entry((rank, panel)).or_insert(0);
                *f = (*f).max(site_index(phase, step, lane));
                assert_eq!(
                    store.get(rank, panel, phase, step, lane).is_some(),
                    existed,
                    "iter {iter}: stale insert changed entry presence"
                );
            }
            // Kill the rank's current incarnation (REBUILD follows: the
            // next incarnation's inserts are accepted again).
            75..=89 => {
                let rank = (rng.next_u64() % RANKS as u64) as usize;
                store.drop_owner_dead(rank, inc[rank]);
                inc[rank] += 1;
                died[rank] = true;
                live.retain(|k, _| k.0 != rank);
            }
            // Global retirement: panels before p are checkpoint-covered.
            _ => {
                let p = (rng.next_u64() % (PANELS as u64 + 1)) as usize;
                store.retire_before(p);
                live.retain(|k, _| k.1 >= p);
            }
        }

        // Frontier agreement, every iteration (cheap).
        for rank in 0..RANKS {
            for panel in 0..=PANELS {
                let model = (panel..PANELS).any(|p| frontier.contains_key(&(rank, p)));
                assert_eq!(
                    store.has_progress_at_or_after(rank, panel),
                    model,
                    "iter {iter}: has_progress_at_or_after({rank}, {panel})"
                );
            }
        }
        assert_eq!(
            store.current_bytes(),
            live.len() as u64 * entry_bytes,
            "iter {iter}: byte accounting drifted"
        );

        // Entry + per-(rank, panel) frontier agreement over every key
        // ever written, periodically (the expensive sweep).
        if iter % 50 == 49 || iter == ITERS - 1 {
            for &(rank, panel, phase, step, lane) in &touched {
                assert_eq!(
                    store.get(rank, panel, phase, step, lane).is_some(),
                    live.contains_key(&(rank, panel, phase, step, lane)),
                    "iter {iter}: entry presence diverged at \
                     ({rank}, {panel}, {phase:?}, {step}, {lane})"
                );
                let model = frontier
                    .get(&(rank, panel))
                    .is_some_and(|&max| max >= site_index(phase, step, lane));
                assert_eq!(
                    store.has_completed(rank, panel, phase, step, lane),
                    model,
                    "iter {iter}: frontier diverged at \
                     ({rank}, {panel}, {phase:?}, {step}, {lane})"
                );
            }
        }
    }
}

// ---------------------------------------------------------------------
// Campaign reproducibility through the public API
// ---------------------------------------------------------------------

#[test]
fn campaign_is_bit_reproducible_from_one_seed() {
    let c = CampaignConfig {
        base: RunConfig { rows: 128, cols: 32, block: 16, procs: 2, ..Default::default() },
        procs: vec![2],
        mtbf_panels: vec![2.0],
        intervals: vec![IntervalChoice::Fixed(0), IntervalChoice::Auto],
        trials: 2,
        max_failures: 4,
        seed: 77,
        check_tol: Some(0.5),
        jobs: 2,
        ..Default::default()
    };
    let body = |c: &CampaignConfig| {
        let mut sink = JsonSink::new();
        run_campaign(c).unwrap().emit(c, &mut sink);
        sink.body()
    };
    assert_eq!(body(&c), body(&c), "one seed, one byte stream");
    // A different seed is a different campaign (overwhelmingly likely to
    // differ in its kill schedules).
    let mut c2 = c.clone();
    c2.seed = 78;
    assert_ne!(body(&c), body(&c2), "seed must actually steer the campaign");
}
