//! Integration: `Semantics::Shrink` / `Semantics::Blank` at the *driver*
//! level. The CAQR coordinator does not renumber mid-factorization, so
//! under these semantics a detected failure surfaces as
//! `Fail::RankFailed { rank }` (rust/src/coordinator/recovery.rs) and
//! the run fails — reporting the id of the rank that died, not a hang
//! and not a REBUILD. (The sim-level semantics demos live in
//! `examples/semantics.rs`.)

use ftcaqr::backend::Backend;
use ftcaqr::config::{Algorithm, RunConfig};
use ftcaqr::coordinator::run_caqr_matrix;
use ftcaqr::fault::{FaultPlan, Phase, ScheduledKill};
use ftcaqr::ft::Semantics;
use ftcaqr::linalg::Matrix;
use ftcaqr::trace::Trace;

fn cfg(semantics: Semantics) -> RunConfig {
    RunConfig {
        rows: 512,
        cols: 128,
        block: 32,
        procs: 4,
        algorithm: Algorithm::FaultTolerant,
        semantics,
        ..Default::default()
    }
}

/// Run with rank 1 killed at panel 0's first update step and return the
/// error text (the run must fail under non-Rebuild semantics).
fn failing_run(semantics: Semantics) -> String {
    let c = cfg(semantics);
    let a = Matrix::randn(c.rows, c.cols, 23);
    let fault = FaultPlan::schedule(vec![ScheduledKill::new(1, 0, 0, Phase::Update)]);
    let err = run_caqr_matrix(c, a, Backend::native(), fault, Trace::disabled())
        .expect_err("non-Rebuild semantics must fail the run");
    format!("{err:#}")
}

#[test]
fn shrink_semantics_reports_the_failed_rank_id() {
    let msg = failing_run(Semantics::Shrink);
    // The first detector is the victim's update-step buddy: it must
    // surface RankFailed with the victim's id — the driver neither
    // rebuilds nor hides who died.
    assert!(
        msg.contains("RankFailed { rank: 1 }"),
        "victim id missing from error: {msg}"
    );
    // The victim's own block is unrecoverable, so its rank is missing
    // from the assembled result.
    assert!(msg.contains("did not complete"), "unexpected failure shape: {msg}");
}

#[test]
fn blank_semantics_reports_the_failed_rank_id() {
    let msg = failing_run(Semantics::Blank);
    assert!(
        msg.contains("RankFailed { rank: 1 }"),
        "victim id missing from error: {msg}"
    );
}

#[test]
fn failed_rank_id_is_deterministic_across_runs() {
    // The detection cascade follows the dataflow, not wall-clock thread
    // timing: the reported victim id is stable run to run.
    let a = failing_run(Semantics::Shrink);
    let b = failing_run(Semantics::Shrink);
    assert!(b.contains("RankFailed { rank: 1 }"), "second run lost the victim id: {b}");
    // Both runs name the same victim (the full cascade text may differ
    // in which secondary detections are recorded, the victim must not).
    assert_eq!(
        a.contains("RankFailed { rank: 1 }"),
        b.contains("RankFailed { rank: 1 }")
    );
}
