//! Integration: observability contract (spans, exports, derived
//! metrics).
//!
//! Three claims are gated here: (1) the trace is a *pure function of
//! the seeded run* — same seed, same kills, `workers = 1` means a
//! byte-identical Perfetto export, clean or faulted, 1-D or 2-D grid;
//! (2) observability is *invisible* — recording spans changes neither
//! the factors nor the simulated clock; (3) the derived metrics
//! (time-to-detect, time-to-rebuild, store high-water, checkpoint
//! bytes, per-phase split) are populated and algebraically consistent
//! under [`Report::absorb`] / [`Report::since`].

use std::sync::Arc;

use ftcaqr::backend::Backend;
use ftcaqr::config::{Algorithm, RunConfig};
use ftcaqr::coordinator::{run_caqr, CaqrOutcome};
use ftcaqr::fault::{FaultPlan, FaultSpec, Phase, ScheduledKill};
use ftcaqr::ft::Semantics;
use ftcaqr::metrics::Report;
use ftcaqr::trace::{SpanKind, Trace};

/// Deterministic base config: `workers = 1` serializes the pool so the
/// interleaving (and therefore the trace) is reproducible; checkpoints
/// every panel so `CheckpointWrite` spans exist.
fn cfg(procs: usize) -> RunConfig {
    RunConfig {
        rows: procs * 64,
        cols: 64,
        block: 16,
        procs,
        workers: 1,
        algorithm: Algorithm::FaultTolerant,
        semantics: Semantics::Rebuild,
        checkpoint_every: 1,
        checkpoint_auto: false,
        seed: 11,
        ..Default::default()
    }
}

fn kills(v: Vec<ScheduledKill>) -> FaultPlan {
    if v.is_empty() {
        FaultPlan::none()
    } else {
        FaultPlan::new(FaultSpec::Schedule { kills: v })
    }
}

fn run(c: &RunConfig, fault: FaultPlan, trace: Arc<Trace>) -> CaqrOutcome {
    run_caqr(c.clone(), Backend::native(), fault, trace).unwrap()
}

/// Run the config twice with fresh traces; both Perfetto exports must
/// be byte-identical.
fn assert_reproducible(c: &RunConfig, mk_kills: impl Fn() -> Vec<ScheduledKill>) -> String {
    let ta = Trace::new();
    let tb = Trace::new();
    run(c, kills(mk_kills()), ta.clone());
    run(c, kills(mk_kills()), tb.clone());
    let (a, b) = (ta.to_perfetto(), tb.to_perfetto());
    assert_eq!(a, b, "same-seed exports diverged ({}x{} P={})", c.rows, c.cols, c.procs);
    a
}

#[test]
fn clean_run_trace_is_byte_identical_and_has_all_phases() {
    let c = cfg(4);
    let j = assert_reproducible(&c, Vec::new);
    // 1-D layout: no row-broadcast exists (Pc = 1), so the expected
    // phases are tsqr/update/checkpoint; bcast is gated in the grid
    // test below.
    for name in ["panel_tsqr", "update_segment", "checkpoint_write"] {
        assert!(j.contains(&format!("\"name\": \"{name}\"")), "export missing {name}: {j}");
    }
    assert!(!j.contains("\"cat\": \"recovery\""), "clean run flagged recovery spans");
}

#[test]
fn faulted_run_trace_is_byte_identical_and_flags_recovery() {
    let c = cfg(4);
    let mk = || vec![ScheduledKill::new(2, 1, 0, Phase::Update)];
    let j = assert_reproducible(&c, mk);
    for name in ["recovery_detect", "recovery_fetch", "recovery_replay"] {
        assert!(j.contains(&format!("\"name\": \"{name}\"")), "export missing {name}");
    }
    assert!(j.contains("\"cat\": \"recovery\""));
    assert!(j.contains("\"recovery\": 1"));
}

#[test]
fn grid_2x2_trace_is_byte_identical_and_attributed() {
    let mut c = cfg(4);
    c.grid_rows = 2;
    c.grid_cols = 2;
    let j = assert_reproducible(&c, Vec::new);
    // 2-D attribution reaches the export: some span sits at grid row 1,
    // column 1, and every rank has a named track.
    assert!(j.contains("\"gr\": 1"), "no span attributed to grid row 1");
    assert!(j.contains("\"gc\": 1"), "no span attributed to grid column 1");
    // The row-broadcast is the 2-D layout's communication step — its
    // spans only exist here (Pc > 1).
    assert!(j.contains("\"name\": \"bcast_factors\""), "2x2 run has no bcast spans");
    for r in 0..4 {
        assert!(j.contains(&format!("\"rank {r}\"")), "missing track for rank {r}");
    }
}

#[test]
fn tracing_changes_neither_factors_nor_simulated_clock() {
    let c = cfg(4);
    let mk = || vec![ScheduledKill::new(3, 1, 0, Phase::Tsqr)];
    let off = run(&c, kills(mk()), Trace::disabled());
    let trace = Trace::new();
    let on = run(&c, kills(mk()), trace.clone());
    assert_eq!(off.r, on.r, "tracing changed the factors");
    assert_eq!(off.reduced, on.reduced);
    assert_eq!(off.report.critical_path, on.report.critical_path);
    assert_eq!(off.report.bytes, on.report.bytes);
    let spans = trace.spans();
    assert!(!spans.is_empty(), "enabled trace recorded no spans");
    assert!(spans.iter().any(|s| s.kind == SpanKind::PanelTsqr));
    assert!(spans.iter().any(|s| s.kind == SpanKind::RecoveryReplay && s.recovery));
}

#[test]
fn ring_overflow_is_bounded_and_accounted_through_a_real_run() {
    let c = cfg(4);
    let trace = Trace::with_capacity(8);
    run(&c, kills(Vec::new()), trace.clone());
    assert!(trace.dropped() > 0, "a full run must overflow an 8-slot ring");
    assert!(trace.len() <= 8 * c.procs, "rings exceeded their bound");
    assert!(trace.to_perfetto().contains("dropped_records"));
}

#[test]
fn kill_run_populates_derived_metrics() {
    let c = cfg(4);
    let out = run(&c, kills(vec![ScheduledKill::new(2, 1, 0, Phase::Update)]), Trace::disabled());
    let r = &out.report;
    assert_eq!(r.failures, 1);
    assert_eq!(r.recoveries, 1);
    assert_eq!(r.detects, 1, "the kill must be detected exactly once");
    assert_eq!(r.rebuilds, 1, "the replacement must finish exactly one replay");
    assert!(r.detect_s_total >= 0.0);
    assert_eq!(r.detect_s_max, r.detect_s_total, "single detect: max == total");
    assert!(r.rebuild_s_total > 0.0, "replay takes simulated time");
    assert_eq!(r.rebuild_s_max, r.rebuild_s_total, "single rebuild: max == total");
    assert!(r.store_peak_bytes > 0, "FT run retains data");
    assert!(r.checkpoints > 0 && r.checkpoint_bytes > 0);
    assert!(r.tsqr_s > 0.0 && r.update_s > 0.0);
    assert_eq!(r.bcast_s, 0.0, "1-D layout has no row-broadcast");
    assert!(r.checkpoint_s > 0.0 && r.recovery_s > 0.0);
    // The Prometheus snapshot surfaces the same derived metrics.
    let prom = ftcaqr::metrics::prom::render(r, &[("job", "test")]);
    assert!(prom.contains("ftcaqr_detect_seconds_total{job=\"test\"}"));
    assert!(prom.contains("ftcaqr_rebuild_seconds_total{job=\"test\"}"));
    assert!(prom.contains("ftcaqr_store_peak_bytes{job=\"test\"}"));
    assert!(prom.contains("ftcaqr_phase_seconds_total{job=\"test\",phase=\"recovery\"}"));
}

// --- Report algebra property tests (seeded LCG, no external crates) ---

/// Minimal LCG; float fields get small *integer* values so f64 addition
/// and subtraction are exact and full-equality assertions are valid.
struct Lcg(u64);

impl Lcg {
    fn next(&mut self) -> u64 {
        self.0 = self.0.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
        self.0 >> 33
    }

    fn int(&mut self, bound: u64) -> u64 {
        self.next() % bound
    }

    fn f(&mut self, bound: u64) -> f64 {
        self.int(bound) as f64
    }

    fn report(&mut self) -> Report {
        Report {
            messages: self.int(1000),
            exchanges: self.int(1000),
            bytes: self.int(1 << 20),
            flops: self.int(1 << 20),
            recoveries: self.int(8),
            failures: self.int(8),
            parks: self.int(100),
            stalls: self.int(4),
            checkpoints: self.int(50),
            checkpoint_bytes: self.int(1 << 16),
            store_peak_bytes: self.int(1 << 16),
            detects: self.int(8),
            detect_s_total: self.f(1000),
            detect_s_max: self.f(1000),
            rebuilds: self.int(8),
            rebuild_s_total: self.f(1000),
            rebuild_s_max: self.f(1000),
            tsqr_s: self.f(1000),
            bcast_s: self.f(1000),
            update_s: self.f(1000),
            checkpoint_s: self.f(1000),
            recovery_s: self.f(1000),
            overhead_pct: self.f(4),
            critical_path: self.f(1000),
            compute_path: self.f(1000),
            comm_path: self.f(1000),
        }
    }
}

fn absorbed(a: &Report, b: &Report) -> Report {
    let mut out = a.clone();
    out.absorb(b);
    out
}

#[test]
fn absorb_is_associative() {
    let mut rng = Lcg(42);
    for case in 0..200 {
        let (a, b, c) = (rng.report(), rng.report(), rng.report());
        let left = absorbed(&absorbed(&a, &b), &c);
        let right = absorbed(&a, &absorbed(&b, &c));
        assert_eq!(left, right, "absorb not associative (case {case})");
    }
}

#[test]
fn absorb_identity_is_default() {
    let mut rng = Lcg(7);
    for _ in 0..100 {
        let a = rng.report();
        assert_eq!(absorbed(&a, &Report::default()), a);
        // Left identity holds on counters and max-gauges; overhead_pct
        // and the path gauges are carried by the non-default side too,
        // so default ⊕ a == a outright.
        assert_eq!(absorbed(&Report::default(), &a), a);
    }
}

#[test]
fn since_inverts_absorb_on_counters() {
    let mut rng = Lcg(1234);
    for case in 0..200 {
        let (a, b) = (rng.report(), rng.report());
        let ab = absorbed(&a, &b);
        let diff = ab.since(&a);
        // Counters round-trip exactly; gauges are documented to come
        // from the later snapshot (`ab`), so expect b's counters with
        // ab's gauges.
        let expected = Report {
            store_peak_bytes: ab.store_peak_bytes,
            detect_s_max: ab.detect_s_max,
            rebuild_s_max: ab.rebuild_s_max,
            overhead_pct: ab.overhead_pct,
            critical_path: ab.critical_path,
            compute_path: ab.compute_path,
            comm_path: ab.comm_path,
            ..b.clone()
        };
        assert_eq!(diff, expected, "since did not invert absorb (case {case})");
    }
}
