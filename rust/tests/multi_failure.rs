//! Integration: multi-failure scenarios beyond single scheduled kills —
//! k independent kills in different panels, kills aimed at a REBUILD
//! replacement (failure during recovery), and correlated buddy-pair
//! kills that destroy both copies of a step's redundancy and therefore
//! must be *reported* as unrecoverable (paper §III-C reconstructs a
//! failed process from exactly one surviving pair member), never hang.

use ftcaqr::backend::Backend;
use ftcaqr::config::{Algorithm, RunConfig};
use ftcaqr::coordinator::run_caqr_matrix;
use ftcaqr::fault::{FaultPlan, Phase, ScheduledKill};
use ftcaqr::ft::Semantics;
use ftcaqr::linalg::Matrix;
use ftcaqr::trace::Trace;

fn cfg(procs: usize) -> RunConfig {
    RunConfig {
        rows: procs * 64,
        cols: 64,
        block: 16,
        procs,
        algorithm: Algorithm::FaultTolerant,
        semantics: Semantics::Rebuild,
        ..Default::default()
    }
}

fn run_with(
    c: &RunConfig,
    a: &Matrix,
    fault: std::sync::Arc<FaultPlan>,
) -> anyhow::Result<ftcaqr::coordinator::CaqrOutcome> {
    run_caqr_matrix(c.clone(), a.clone(), Backend::native(), fault, Trace::disabled())
}

#[test]
fn disjoint_panel_kills_both_recover() {
    // k = 2 independent failures in different panels: both REBUILD
    // replays succeed and the result is bitwise identical.
    let c = cfg(8);
    let a = Matrix::randn(c.rows, c.cols, 41);
    let clean = run_with(&c, &a, FaultPlan::none()).unwrap();
    let failed = run_with(
        &c,
        &a,
        FaultPlan::schedule(vec![
            ScheduledKill::new(2, 0, 0, Phase::Update),
            ScheduledKill::new(5, 1, 0, Phase::Update),
        ]),
    )
    .unwrap();
    assert_eq!(failed.report.failures, 2);
    assert_eq!(failed.report.recoveries, 2);
    assert_eq!(clean.r, failed.r);
    assert_eq!(clean.reduced, failed.reduced);
}

#[test]
fn kill_during_rebuild_is_survived() {
    // The first replacement (incarnation 1) is itself killed at the very
    // start of its replay; a second REBUILD completes the recovery.
    let c = cfg(4);
    let a = Matrix::randn(c.rows, c.cols, 43);
    let clean = run_with(&c, &a, FaultPlan::none()).unwrap();
    let failed = run_with(
        &c,
        &a,
        FaultPlan::schedule(vec![
            ScheduledKill::new(2, 1, 0, Phase::Update),
            ScheduledKill::new(2, 0, 0, Phase::Tsqr).at_incarnation(1),
        ]),
    )
    .unwrap();
    // Two deaths (original + first replacement), one completed recovery
    // (only the final incarnation finishes its replay).
    assert_eq!(failed.report.failures, 2);
    assert_eq!(failed.report.recoveries, 1);
    assert_eq!(clean.r, failed.r);
}

#[test]
fn buddy_pair_simultaneous_kill_is_unrecoverable_not_a_hang() {
    // Ranks 2 and 3 are exchange buddies at tree step 0; killing both at
    // step 1 (a node crash) destroys BOTH retained copies of their
    // completed step-0 state. The paper's single-buddy protocol cannot
    // reconstruct it: the run must terminate with an unrecoverable
    // error — not deadlock, and not silently recompute outside the
    // protocol.
    let c = cfg(4);
    let a = Matrix::randn(c.rows, c.cols, 47);
    let res = run_with(&c, &a, FaultPlan::kill_pair_at((2, 3), 0, 1, Phase::Tsqr));
    let err = format!("{:#}", res.expect_err("buddy-pair kill must fail the run"));
    assert!(
        err.contains("unrecoverable"),
        "error should report lost redundancy, got: {err}"
    );
}

#[test]
fn simultaneous_kills_of_non_buddies_recover() {
    // Simultaneity itself is not fatal: ranks 1 and 2 die at the same
    // instant, but their step-0 retention buddies (ranks 0 and 3) are
    // alive and still hold the redundant copies, so both replays succeed.
    let c = cfg(4);
    let a = Matrix::randn(c.rows, c.cols, 53);
    let clean = run_with(&c, &a, FaultPlan::none()).unwrap();
    let failed = run_with(&c, &a, FaultPlan::kill_pair_at((1, 2), 0, 1, Phase::Tsqr)).unwrap();
    assert_eq!(failed.report.failures, 2);
    assert_eq!(failed.report.recoveries, 2);
    assert_eq!(clean.r, failed.r);
}

#[test]
fn buddy_pair_kill_before_any_shared_step_recovers() {
    // The same correlated crash aimed at step 0 — BEFORE the pair has
    // completed (and retained) anything together. Nothing is lost, both
    // replacements re-enter step 0 live against each other, and the run
    // completes identically.
    let c = cfg(4);
    let a = Matrix::randn(c.rows, c.cols, 59);
    let clean = run_with(&c, &a, FaultPlan::none()).unwrap();
    let failed = run_with(&c, &a, FaultPlan::kill_pair_at((2, 3), 0, 0, Phase::Tsqr)).unwrap();
    assert_eq!(failed.report.failures, 2);
    assert_eq!(failed.report.recoveries, 2);
    assert_eq!(clean.r, failed.r);
}

#[test]
fn large_p_multi_failure_gram_identity() {
    // Scale + faults together on the pooled scheduler: P = 64 ranks on
    // an auto-sized pool, three kills across panels/phases, Gram-check.
    let procs = 64;
    let c = RunConfig {
        rows: procs * 16,
        cols: 32,
        block: 8,
        procs,
        algorithm: Algorithm::FaultTolerant,
        ..Default::default()
    };
    let a = Matrix::randn(c.rows, c.cols, 61);
    let out = run_with(
        &c,
        &a,
        FaultPlan::schedule(vec![
            ScheduledKill::new(11, 0, 0, Phase::Update),
            ScheduledKill::new(30, 1, 2, Phase::Tsqr),
            ScheduledKill::new(62, 2, 0, Phase::Update),
        ]),
    )
    .unwrap();
    assert_eq!(out.report.failures, 3);
    assert_eq!(out.report.recoveries, 3);
    let res = out.residual.expect("verify on");
    assert!(res < 1e-3, "residual {res}");
}
