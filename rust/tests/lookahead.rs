//! Integration: the lookahead dataflow engine preserves bitwise
//! determinism. For every shape, `lookahead = L > 0` must produce
//! factors bitwise identical to the lockstep `L = 0` schedule — the
//! engine may reorder *when* work happens (next panel's TSQR overlaps
//! the far-trailing update), never *what* is computed — including under
//! fault injection with REBUILD recovery of a rank holding multiple
//! in-flight panels, and through the multi-tenant service. The pipeline
//! should also shorten the simulated makespan on multi-panel runs.

use ftcaqr::backend::Backend;
use ftcaqr::config::{Algorithm, RunConfig};
use ftcaqr::coordinator::{run_caqr_matrix, CaqrOutcome};
use ftcaqr::fault::{FaultPlan, Phase, ScheduledKill};
use ftcaqr::ft::Semantics;
use ftcaqr::linalg::Matrix;
use ftcaqr::trace::Trace;

fn cfg(
    rows: usize,
    cols: usize,
    block: usize,
    procs: usize,
    alg: Algorithm,
    lookahead: usize,
) -> RunConfig {
    RunConfig {
        rows,
        cols,
        block,
        procs,
        algorithm: alg,
        lookahead,
        semantics: Semantics::Rebuild,
        ..Default::default()
    }
}

fn run(c: &RunConfig, a: &Matrix, kills: Vec<ScheduledKill>) -> CaqrOutcome {
    let fault =
        if kills.is_empty() { FaultPlan::none() } else { FaultPlan::schedule(kills) };
    run_caqr_matrix(c.clone(), a.clone(), Backend::native(), fault, Trace::disabled())
        .unwrap()
}

#[test]
fn factors_bitwise_identical_across_depths_both_algorithms() {
    for alg in [Algorithm::Plain, Algorithm::FaultTolerant] {
        let a = Matrix::randn(512, 128, 42);
        let base = run(&cfg(512, 128, 32, 4, alg, 0), &a, vec![]);
        for l in [1usize, 2, 4] {
            let out = run(&cfg(512, 128, 32, 4, alg, l), &a, vec![]);
            assert_eq!(base.r, out.r, "{alg:?} L={l} changed R");
            assert_eq!(base.reduced, out.reduced, "{alg:?} L={l} changed [R;0]");
        }
    }
}

#[test]
fn shape_sweep_matches_lockstep_bitwise() {
    // The correctness-suite shapes: process counts (odd trees included),
    // block sizes, square matrix (ranks retire panel by panel).
    let shapes: &[(usize, usize, usize, usize)] = &[
        (256, 64, 16, 4),
        (320, 64, 16, 5),
        (512, 128, 8, 4),
        (256, 256, 32, 4),
        (192, 64, 16, 3),
    ];
    for &(rows, cols, block, procs) in shapes {
        let a = Matrix::randn(rows, cols, 9);
        let base = run(&cfg(rows, cols, block, procs, Algorithm::FaultTolerant, 0), &a, vec![]);
        let piped = run(&cfg(rows, cols, block, procs, Algorithm::FaultTolerant, 2), &a, vec![]);
        assert_eq!(base.r, piped.r, "{rows}x{cols} b={block} P={procs}");
        assert_eq!(base.reduced, piped.reduced, "{rows}x{cols} b={block} P={procs}");
    }
}

#[test]
fn verification_holds_under_lookahead() {
    let a = Matrix::randn(512, 128, 5);
    let out = run(&cfg(512, 128, 32, 4, Algorithm::FaultTolerant, 2), &a, vec![]);
    let res = out.residual.expect("verification enabled");
    assert!(res < 5e-4, "residual {res}");
    assert!(out.r.is_upper_triangular(1e-6));
}

#[test]
fn rebuild_of_rank_with_multiple_inflight_panels_matches_lockstep() {
    // Kill a rank at a late panel's update step under L = 2: at that
    // moment the victim holds several in-flight panels (far segments of
    // earlier panels draining while later TSQRs run). The REBUILD
    // replacement must reconstruct the full multi-panel state from one
    // buddy per step and land bitwise on the lockstep factors.
    let c0 = cfg(512, 128, 32, 4, Algorithm::FaultTolerant, 0);
    let a = Matrix::randn(c0.rows, c0.cols, 3);
    let clean = run(&c0, &a, vec![]);
    for victim in [1usize, 2] {
        let failed = run(
            &cfg(512, 128, 32, 4, Algorithm::FaultTolerant, 2),
            &a,
            vec![ScheduledKill::new(victim, 2, 0, Phase::Update)],
        );
        assert_eq!(failed.report.failures, 1, "victim {victim}");
        assert_eq!(failed.report.recoveries, 1, "victim {victim}");
        assert_eq!(clean.r, failed.r, "victim {victim}");
        assert_eq!(clean.reduced, failed.reduced, "victim {victim}");
    }
}

#[test]
fn tsqr_phase_failure_recovers_bitwise_under_lookahead() {
    let c0 = cfg(512, 128, 32, 4, Algorithm::FaultTolerant, 0);
    let a = Matrix::randn(c0.rows, c0.cols, 11);
    let clean = run(&c0, &a, vec![]);
    let failed = run(
        &cfg(512, 128, 32, 4, Algorithm::FaultTolerant, 1),
        &a,
        vec![ScheduledKill::new(1, 2, 1, Phase::Tsqr)],
    );
    assert_eq!(failed.report.failures, 1);
    assert_eq!(failed.report.recoveries, 1);
    assert_eq!(clean.r, failed.r);
}

#[test]
fn checkpoint_barrier_preserves_snapshot_bytes() {
    // Checkpoints are admission barriers: the snapshot exchanged at each
    // boundary must be the lockstep one, so traffic and factors match.
    let mut c0 = cfg(512, 128, 32, 4, Algorithm::FaultTolerant, 0);
    c0.checkpoint_every = 2;
    let mut c2 = c0.clone();
    c2.lookahead = 2;
    let a = Matrix::randn(c0.rows, c0.cols, 13);
    let base = run(&c0, &a, vec![]);
    let piped = run(&c2, &a, vec![]);
    assert_eq!(base.r, piped.r);
    assert_eq!(base.report.bytes, piped.report.bytes, "checkpoint traffic must match");
}

#[test]
fn lookahead_shortens_simulated_makespan() {
    // The point of the pipeline: panel k+1's R messages are produced
    // before panel k's far-trailing updates drain, so the simulated
    // critical path of a multi-panel run drops at L >= 1.
    let a = Matrix::randn(1024, 256, 7);
    let base = run(&cfg(1024, 256, 32, 8, Algorithm::FaultTolerant, 0), &a, vec![]);
    let piped = run(&cfg(1024, 256, 32, 8, Algorithm::FaultTolerant, 2), &a, vec![]);
    assert_eq!(base.r, piped.r);
    // Demand a real margin (>= 1%), not bare inequality: at L > 0 the
    // simulated clock can jitter slightly with the order a rank observes
    // exchange completions (DESIGN.md "Lookahead dataflow engine"), and
    // the pipeline's structural win on this many-panel shape is far
    // larger than that jitter.
    assert!(
        piped.report.critical_path < base.report.critical_path * 0.99,
        "L=2 makespan {} should beat L=0 makespan {} by >= 1%",
        piped.report.critical_path,
        base.report.critical_path
    );
}

#[test]
fn deterministic_given_seed_under_lookahead() {
    let c = cfg(256, 64, 16, 4, Algorithm::FaultTolerant, 2);
    let a = Matrix::randn(c.rows, c.cols, 17);
    let o1 = run(&c, &a, vec![]);
    let o2 = run(&c, &a, vec![]);
    assert_eq!(o1.r, o2.r);
    assert_eq!(o1.report.exchanges, o2.report.exchanges);
    assert_eq!(o1.report.bytes, o2.report.bytes);
}

#[test]
fn service_jobs_with_lookahead_match_solo_lockstep() {
    use ftcaqr::service::{JobOutput, JobSpec, Service, ServiceConfig};
    let svc = Service::new(ServiceConfig {
        workers: 2,
        max_inflight_ranks: 64,
        batch_max: 1,
    });
    let mk = |lookahead| RunConfig {
        rows: 256,
        cols: 64,
        block: 16,
        procs: 4,
        seed: 21,
        lookahead,
        ..Default::default()
    };
    let h0 = svc.submit(JobSpec::Caqr { cfg: mk(0), kills: vec![] }).unwrap();
    let h2 = svc.submit(JobSpec::Caqr { cfg: mk(2), kills: vec![] }).unwrap();
    let o0 = h0.wait();
    let o2 = h2.wait();
    let r_of = |o: ftcaqr::service::JobOutcome| match o.output {
        Ok(JobOutput::Caqr(out)) => out.r,
        other => panic!("caqr output expected, got {other:?}"),
    };
    assert_eq!(r_of(o0), r_of(o2), "service tenants must agree across depths");
}
