//! Integration over the PJRT runtime: load the real AOT artifacts, run
//! every op against the pure-Rust oracle, and run a full XLA-backed
//! FT-CAQR with a failure. Skipped (cleanly) when `make artifacts` has
//! not produced the artifact directory.

use std::collections::BTreeMap;
use std::path::PathBuf;
use std::sync::Arc;

use ftcaqr::backend::Backend;
use ftcaqr::config::{Algorithm, RunConfig};
use ftcaqr::coordinator::run_caqr_matrix;
use ftcaqr::fault::{FaultPlan, FaultSpec, Phase, ScheduledKill};
use ftcaqr::linalg::{self, rel_err, Matrix};
use ftcaqr::runtime::Engine;
use ftcaqr::trace::Trace;

fn artifact_dir() -> Option<PathBuf> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    dir.join("manifest.txt").exists().then_some(dir)
}

macro_rules! require_artifacts {
    () => {
        match artifact_dir() {
            Some(d) => d,
            None => {
                eprintln!("skipping: artifacts/ not built (run `make artifacts`)");
                return;
            }
        }
    };
}

#[test]
fn xla_ops_match_native_oracle() {
    let dir = require_artifacts!();
    let engine = Engine::start(&dir).unwrap();
    let xla = Backend::xla(engine);

    // panel_qr (exact shape + padded shape)
    for m in [64, 100, 128] {
        let a = Matrix::randn(m, 16, m as u64);
        let x = xla.panel_qr(&a).unwrap();
        let n = linalg::householder_qr(&a);
        assert!(rel_err(&x.r, &n.r) < 1e-3, "panel_qr m={m} R");
        assert!(rel_err(&x.y, &n.y) < 1e-3, "panel_qr m={m} Y");
        assert!(rel_err(&x.t, &n.t) < 1e-3, "panel_qr m={m} T");
    }

    // tsqr_merge
    let r0 = Matrix::randn(16, 16, 1).triu();
    let r1 = Matrix::randn(16, 16, 2).triu();
    let mx = xla.tsqr_merge(&r0, &r1).unwrap();
    let (ny0, ny1, nt, nr) = linalg::tsqr_merge(&r0, &r1);
    assert!(rel_err(&mx.y0, &ny0) < 1e-3);
    assert!(rel_err(&mx.y1, &ny1) < 1e-3);
    assert!(rel_err(&mx.t, &nt) < 1e-3);
    assert!(rel_err(&mx.r, &nr) < 1e-3);

    // leaf_apply with padding on both dims
    let f = linalg::householder_qr(&Matrix::randn(100, 16, 3));
    let c = Matrix::randn(100, 50, 4);
    let got = xla.leaf_apply(&f.y, &f.t, &c).unwrap();
    let want = linalg::leaf_apply(&f.y, &f.t, &c);
    assert!(rel_err(&got, &want) < 1e-3);

    // tree_update + recover
    let c0 = Matrix::randn(16, 48, 5);
    let c1 = Matrix::randn(16, 48, 6);
    let stx = xla.tree_update(&c0, &c1, &mx.y1, &mx.t).unwrap();
    let stn = linalg::tree_update(&c0, &c1, &ny1, &nt);
    assert!(rel_err(&stx.w, &stn.w) < 1e-3);
    assert!(rel_err(&stx.c0, &stn.c0) < 1e-3);
    assert!(rel_err(&stx.c1, &stn.c1) < 1e-3);
    let rec = xla.recover(&c1, &mx.y1, &stx.w).unwrap();
    assert!(rel_err(&rec, &stn.c1) < 1e-3);
}

#[test]
fn engine_caches_compilations() {
    let dir = require_artifacts!();
    let engine = Engine::start(&dir).unwrap();
    let want = BTreeMap::from([("b", 16usize)]);
    let entry = engine.manifest().select("tsqr_merge", &want).unwrap().clone();
    let r0 = Matrix::randn(16, 16, 1).triu();
    let r1 = Matrix::randn(16, 16, 2).triu();
    for _ in 0..5 {
        engine.exec(&entry, vec![r0.clone(), r1.clone()]).unwrap();
    }
    let (execs, compiles, _, _) = engine.stats().snapshot();
    assert_eq!(execs, 5);
    assert_eq!(compiles, 1, "executable must be compiled once and cached");
}

#[test]
fn engine_rejects_bad_shapes() {
    let dir = require_artifacts!();
    let engine = Engine::start(&dir).unwrap();
    let want = BTreeMap::from([("b", 16usize)]);
    let entry = engine.manifest().select("tsqr_merge", &want).unwrap().clone();
    // wrong arity
    assert!(engine.exec(&entry, vec![Matrix::eye(16)]).is_err());
    // wrong shape
    assert!(engine
        .exec(&entry, vec![Matrix::eye(8), Matrix::eye(8)])
        .is_err());
}

#[test]
fn xla_backed_caqr_with_recovery_matches_native() {
    let dir = require_artifacts!();
    let cfg = RunConfig {
        rows: 512,
        cols: 128,
        block: 32,
        procs: 4,
        algorithm: Algorithm::FaultTolerant,
        ..Default::default()
    };
    let a = Matrix::randn(cfg.rows, cfg.cols, 9);
    let kills = vec![ScheduledKill::new(2, 1, 0, Phase::Update)];

    let engine = Engine::start(&dir).unwrap();
    let xla_out = run_caqr_matrix(
        cfg.clone(),
        a.clone(),
        Backend::xla(engine),
        FaultPlan::new(FaultSpec::Schedule { kills }),
        Trace::disabled(),
    )
    .unwrap();
    assert_eq!(xla_out.report.recoveries, 1);
    let res = xla_out.residual.unwrap();
    assert!(res < 1e-3, "xla residual {res}");

    let native_out = run_caqr_matrix(
        cfg,
        a,
        Backend::native(),
        FaultPlan::none(),
        Trace::disabled(),
    )
    .unwrap();
    // Same factorization up to f32 kernel-order effects.
    assert!(rel_err(&xla_out.r, &native_out.r) < 5e-3);
}
