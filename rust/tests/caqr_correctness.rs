//! Integration: the distributed CAQR factorization is numerically correct
//! across algorithms, shapes, process counts and block sizes (native
//! backend; the XLA path is covered in `runtime_xla.rs`).

use std::sync::Arc;

use ftcaqr::backend::Backend;
use ftcaqr::config::{Algorithm, RunConfig};
use ftcaqr::coordinator::{run_caqr_matrix, run_caqr_simple};
use ftcaqr::fault::FaultPlan;
use ftcaqr::linalg::Matrix;
use ftcaqr::trace::Trace;

fn cfg(rows: usize, cols: usize, block: usize, procs: usize, alg: Algorithm) -> RunConfig {
    RunConfig {
        rows,
        cols,
        block,
        procs,
        algorithm: alg,
        ..Default::default()
    }
}

fn assert_good(out: &ftcaqr::coordinator::CaqrOutcome, tag: &str) {
    let res = out.residual.expect("verification enabled");
    assert!(res < 5e-4, "{tag}: residual {res}");
    assert!(out.lower_defect < 1e-3, "{tag}: lower defect {}", out.lower_defect);
    assert!(out.r.is_upper_triangular(1e-6), "{tag}: R not triangular");
}

#[test]
fn default_config_both_algorithms() {
    for alg in [Algorithm::Plain, Algorithm::FaultTolerant] {
        let out =
            run_caqr_simple(RunConfig { algorithm: alg, ..Default::default() }).unwrap();
        assert_good(&out, &format!("{alg:?}"));
    }
}

#[test]
fn sweep_process_counts() {
    for procs in [1, 2, 3, 4, 5, 8] {
        for alg in [Algorithm::Plain, Algorithm::FaultTolerant] {
            let c = cfg(procs * 64, 64, 16, procs, alg);
            let out = run_caqr_simple(c).unwrap();
            assert_good(&out, &format!("P={procs} {alg:?}"));
        }
    }
}

#[test]
fn sweep_block_sizes() {
    for block in [8, 16, 32] {
        let c = cfg(512, 128, block, 4, Algorithm::FaultTolerant);
        let out = run_caqr_simple(c).unwrap();
        assert_good(&out, &format!("b={block}"));
    }
}

#[test]
fn square_matrix() {
    // cols == rows/P boundary behaviour: ranks retire panel by panel.
    let c = cfg(256, 256, 32, 4, Algorithm::FaultTolerant);
    let out = run_caqr_simple(c).unwrap();
    assert_good(&out, "square");
}

#[test]
fn single_panel_matrix() {
    // cols == block: the run is a pure TSQR (no trailing update).
    let c = cfg(256, 32, 32, 4, Algorithm::FaultTolerant);
    let out = run_caqr_simple(c).unwrap();
    assert_good(&out, "single-panel");
}

#[test]
fn plain_and_ft_produce_identical_r() {
    // Same tree, same merges — the FT algorithm must not change the
    // numerics at all (paper: redundancy only, no recomputation).
    let a = Matrix::randn(512, 128, 42);
    let mk = |alg| {
        run_caqr_matrix(
            cfg(512, 128, 32, 4, alg),
            a.clone(),
            Backend::native(),
            FaultPlan::none(),
            Trace::disabled(),
        )
        .unwrap()
    };
    let plain = mk(Algorithm::Plain);
    let ft = mk(Algorithm::FaultTolerant);
    assert_eq!(plain.r, ft.r, "FT changed the numerics");
}

#[test]
fn matches_single_process_reference() {
    // P-process run equals the P=1 run (which is plain blocked QR).
    let a = Matrix::randn(256, 64, 7);
    let multi = run_caqr_matrix(
        cfg(256, 64, 16, 4, Algorithm::FaultTolerant),
        a.clone(),
        Backend::native(),
        FaultPlan::none(),
        Trace::disabled(),
    )
    .unwrap();
    let single = run_caqr_matrix(
        cfg(256, 64, 16, 1, Algorithm::FaultTolerant),
        a.clone(),
        Backend::native(),
        FaultPlan::none(),
        Trace::disabled(),
    )
    .unwrap();
    // Both are valid QRs of the same matrix: compare RᵀR (sign-free).
    use ftcaqr::linalg::{gemm, rel_err, Trans};
    let g1 = gemm(Trans::Yes, Trans::No, 1.0, &multi.r, &multi.r);
    let g2 = gemm(Trans::Yes, Trans::No, 1.0, &single.r, &single.r);
    assert!(rel_err(&g1, &g2) < 1e-4);
}

#[test]
fn deterministic_given_seed() {
    let c = cfg(256, 64, 16, 4, Algorithm::FaultTolerant);
    let o1 = run_caqr_simple(c.clone()).unwrap();
    let o2 = run_caqr_simple(c).unwrap();
    assert_eq!(o1.r, o2.r);
    assert_eq!(o1.report.messages, o2.report.messages);
    assert_eq!(o1.report.exchanges, o2.report.exchanges);
}

#[test]
fn ft_uses_exchanges_plain_uses_messages() {
    // The communication *pattern* claim: Algorithm 1 = one-way sends,
    // Algorithm 2 = sendrecv exchanges (paper III-C).
    let p = run_caqr_simple(cfg(512, 128, 32, 4, Algorithm::Plain)).unwrap();
    let f = run_caqr_simple(cfg(512, 128, 32, 4, Algorithm::FaultTolerant)).unwrap();
    assert_eq!(p.report.exchanges, 0);
    assert!(p.report.messages > 0);
    assert_eq!(f.report.messages, 0);
    assert!(f.report.exchanges > 0);
}

#[test]
fn ft_critical_path_overhead_is_small() {
    // Paper C1: failure-free critical path of Algorithm 2 ≈ Algorithm 1
    // on dual-channel links (it is *shorter* on the update tree, since
    // one exchange replaces two serialized one-ways).
    let p = run_caqr_simple(cfg(1024, 256, 32, 8, Algorithm::Plain)).unwrap();
    let f = run_caqr_simple(cfg(1024, 256, 32, 8, Algorithm::FaultTolerant)).unwrap();
    let ratio = f.report.critical_path / p.report.critical_path;
    assert!(
        ratio < 1.25,
        "FT critical path ratio {ratio} too large (cp_ft={}, cp_plain={})",
        f.report.critical_path,
        p.report.critical_path
    );
}

#[test]
fn ft_extra_flops_bounded() {
    // Paper C4: the FT variant buys redundancy with extra computation
    // (both pair members compute merges/updates). The overhead must be
    // present but bounded (< 2x for these shapes).
    let p = run_caqr_simple(cfg(512, 128, 32, 4, Algorithm::Plain)).unwrap();
    let f = run_caqr_simple(cfg(512, 128, 32, 4, Algorithm::FaultTolerant)).unwrap();
    assert!(f.backend_flops > p.backend_flops);
    assert!((f.backend_flops as f64) < 2.0 * p.backend_flops as f64);
}

#[test]
fn checkpoint_traffic_accounted() {
    let mut c = cfg(512, 128, 32, 4, Algorithm::Plain);
    c.checkpoint_every = 2;
    let with = run_caqr_simple(c).unwrap();
    let without = run_caqr_simple(cfg(512, 128, 32, 4, Algorithm::Plain)).unwrap();
    assert!(with.report.bytes > without.report.bytes);
    assert_good(&with, "checkpointed");
}

#[test]
fn rejects_invalid_config() {
    assert!(run_caqr_simple(cfg(100, 64, 16, 3, Algorithm::Plain)).is_err());
}
