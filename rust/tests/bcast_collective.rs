//! Integration: the row-broadcast collective engine. Pins the central
//! contract — a schedule moves bytes, never operand values, so the
//! factors are bitwise identical across flat / binomial / segmented
//! shapes in both the FT (store-pull) and plain (message) data paths,
//! and the payload byte totals agree too (only logical-clock values and
//! hop counters may differ). Also exercises the FT relay fault paths on
//! a 2 x 4 grid: a relay dying mid-broadcast (its children fall back to
//! the root's published copy) and the root itself dying before the
//! bundle is published.

use ftcaqr::backend::Backend;
use ftcaqr::config::{Algorithm, BcastKind, RunConfig};
use ftcaqr::coordinator::run_caqr_matrix;
use ftcaqr::fault::{FaultPlan, Phase, ScheduledKill};
use ftcaqr::ft::Semantics;
use ftcaqr::linalg::Matrix;
use ftcaqr::trace::Trace;

/// 2 x 4 grid, 4 panels: panel 0 broadcasts over all four grid columns
/// (binomial: root 0 relays through 1 -> 3 and 2). `seg_bytes = 4096`
/// is below the leaf-Y matrix (128 x 16 f32 = 8 KiB), so a segmented
/// run really splits the bundle.
fn cfg(kind: BcastKind) -> RunConfig {
    RunConfig {
        rows: 256,
        cols: 64,
        block: 16,
        procs: 8,
        grid_rows: 2,
        grid_cols: 4,
        algorithm: Algorithm::FaultTolerant,
        semantics: Semantics::Rebuild,
        bcast: kind,
        seg_bytes: 4096,
        ..Default::default()
    }
}

fn run_with(
    c: &RunConfig,
    a: &Matrix,
    fault: std::sync::Arc<FaultPlan>,
) -> ftcaqr::coordinator::CaqrOutcome {
    run_caqr_matrix(c.clone(), a.clone(), Backend::native(), fault, Trace::disabled()).unwrap()
}

const KINDS: [BcastKind; 3] = [BcastKind::Flat, BcastKind::Binomial, BcastKind::Segmented];

#[test]
fn ft_schedules_are_bitwise_identical_and_byte_equal() {
    // FT mode: every non-root member pulls the published bundle exactly
    // once whatever the schedule, so message counts, payload bytes, and
    // broadcast hop counts all match across kinds — the shapes differ
    // only in *when* the logical clock says each pull completes.
    let a = Matrix::randn(256, 64, 107);
    let runs: Vec<_> = KINDS
        .iter()
        .map(|&k| run_with(&cfg(k), &a, FaultPlan::none()))
        .collect();
    let flat = &runs[0];
    for other in &runs[1..] {
        assert_eq!(flat.r, other.r);
        assert_eq!(flat.reduced, other.reduced);
        assert_eq!(flat.report.messages, other.report.messages);
        assert_eq!(flat.report.bytes, other.report.bytes);
        assert_eq!(flat.report.bcast_bytes, other.report.bcast_bytes);
        assert_eq!(flat.report.bcast_hops, other.report.bcast_hops);
    }
    // Panel 0 has 4 member columns: flat is one hop deep, the binomial
    // tree two (virtual member 3 = binary 11 is two relays down).
    assert_eq!(runs[0].report.bcast_depth, 1);
    assert_eq!(runs[1].report.bcast_depth, 2);
    assert_eq!(runs[2].report.bcast_depth, 2);
}

#[test]
fn plain_schedules_are_bitwise_identical_and_byte_equal() {
    // Plain mode moves real messages along the tree edges. Every kind
    // crosses members-1 edges per grid row carrying the full bundle, so
    // payload bytes agree everywhere; segmentation splits each edge's
    // bundle into multiple sends, so only the segmented run may have
    // more messages (and more hops), never more bytes.
    let a = Matrix::randn(256, 64, 109);
    let mk = |k| {
        let mut c = cfg(k);
        c.algorithm = Algorithm::Plain;
        c
    };
    let runs: Vec<_> = KINDS
        .iter()
        .map(|&k| run_with(&mk(k), &a, FaultPlan::none()))
        .collect();
    let (flat, binom, seg) = (&runs[0], &runs[1], &runs[2]);
    for other in [binom, seg] {
        assert_eq!(flat.r, other.r);
        assert_eq!(flat.reduced, other.reduced);
        assert_eq!(flat.report.bytes, other.report.bytes);
        assert_eq!(flat.report.bcast_bytes, other.report.bcast_bytes);
    }
    assert_eq!(flat.report.messages, binom.report.messages);
    assert_eq!(flat.report.bcast_hops, binom.report.bcast_hops);
    assert!(
        seg.report.bcast_hops > binom.report.bcast_hops,
        "segmented pipelining must add hops: {} vs {}",
        seg.report.bcast_hops,
        binom.report.bcast_hops
    );
    let res = binom.residual.expect("verify on");
    assert!(res < 1e-3, "residual {res}");
}

#[test]
fn ft_faulted_runs_match_clean_under_every_schedule() {
    // A receiver-side kill mid-broadcast (rank 5 = grid (1,1), a relay
    // under the binomial shapes) must recover bitwise under every
    // schedule kind, and all of them must agree with the clean run.
    let a = Matrix::randn(256, 64, 113);
    let clean = run_with(&cfg(BcastKind::Flat), &a, FaultPlan::none());
    for kind in KINDS {
        let failed = run_with(
            &cfg(kind),
            &a,
            FaultPlan::schedule(vec![ScheduledKill::new(5, 0, 0, Phase::Bcast)]),
        );
        assert_eq!(failed.report.failures, 1, "{kind:?}");
        assert_eq!(failed.report.recoveries, 1, "{kind:?}");
        assert_eq!(clean.r, failed.r, "{kind:?}");
        assert_eq!(clean.reduced, failed.reduced, "{kind:?}");
    }
}

#[test]
fn binomial_relay_death_falls_back_to_the_root() {
    // Rank 1 = grid (0,1) is virtual member 1 of panel 0's broadcast —
    // the relay that feeds member 3 (rank 3). Kill it at its Bcast site:
    // rank 3 either falls back to the root's published copy (relay seen
    // dead) or pulls the replacement's republished one; both paths carry
    // the same bits, and the run must match the clean factors exactly.
    let c = cfg(BcastKind::Binomial);
    let a = Matrix::randn(c.rows, c.cols, 127);
    let clean = run_with(&c, &a, FaultPlan::none());
    let failed = run_with(
        &c,
        &a,
        FaultPlan::schedule(vec![ScheduledKill::new(1, 0, 0, Phase::Bcast)]),
    );
    assert_eq!(failed.report.failures, 1);
    assert_eq!(failed.report.recoveries, 1);
    assert_eq!(clean.r, failed.r);
    assert_eq!(clean.reduced, failed.reduced);
}

#[test]
fn binomial_root_death_mid_broadcast_recovers() {
    // The root of panel 0's broadcast (rank 0) dies after TSQR but
    // before publishing the bundle. Its relays park on the missing
    // store entry; the replacement replays TSQR, republishes, and the
    // tree drains — bitwise identical to the clean run.
    let c = cfg(BcastKind::Binomial);
    let a = Matrix::randn(c.rows, c.cols, 131);
    let clean = run_with(&c, &a, FaultPlan::none());
    let failed = run_with(
        &c,
        &a,
        FaultPlan::schedule(vec![ScheduledKill::new(0, 0, 0, Phase::Bcast)]),
    );
    assert_eq!(failed.report.failures, 1);
    assert_eq!(failed.report.recoveries, 1);
    assert_eq!(clean.r, failed.r);
    assert_eq!(clean.reduced, failed.reduced);
}

#[test]
fn binomial_beats_flat_on_comm_path_with_fat_links() {
    // The headline claim, in miniature: with a bandwidth-dominated cost
    // model (beta raised so a bundle transmission dwarfs alpha), the
    // binomial schedule's O(log Pc) root serialization must strictly cut
    // the simulated communication critical path vs the flat O(Pc) one —
    // on the same matrix, with (per the tests above) identical factors.
    // cols = 128 gives eight panels, so five of them broadcast over all
    // four grid columns and the per-panel gap compounds.
    let a = Matrix::randn(256, 128, 137);
    let mk = |k| {
        let mut c = cfg(k);
        c.cols = 128;
        c.cost.beta = 1e-9;
        c
    };
    let flat = run_with(&mk(BcastKind::Flat), &a, FaultPlan::none());
    let binom = run_with(&mk(BcastKind::Binomial), &a, FaultPlan::none());
    assert_eq!(flat.reduced, binom.reduced);
    assert!(
        binom.report.comm_path < flat.report.comm_path,
        "binomial {} !< flat {}",
        binom.report.comm_path,
        flat.report.comm_path
    );
}
